//! Chunk leases — one rank chunk evaluated to a *deterministic* partial.
//!
//! A lease is the unit of restartable work: given the same matrix, the
//! same Pascal table and the same [`Chunk`], `run_chunk` always produces
//! the bitwise-identical partial, because every accumulation inside a
//! chunk happens in rank order on a single thread. The coordinator's
//! worker loops execute leases back-to-back in-process; the durable jobs
//! subsystem ([`crate::jobs`]) executes exactly the same leases but
//! journals each result, which is what makes an interrupted sweep
//! resumable without changing the final bits.
//!
//! Two runners cover the engine matrix:
//!
//! * [`LeaseRunner`] — float path, wrapping either a lane engine
//!   ([`DetEngine`]: `cpu-lu` batches, XLA handles) or the
//!   prefix-factored Laplace engine ([`PrefixEngine`]).
//! * [`ExactLeaseRunner`] — the `i128` twin (per-term Bareiss, or exact
//!   prefix cofactors shared per sibling block).
//!
//! All scratch lives in the runner and is reused across leases, so the
//! steady-state hot path allocates nothing per chunk.
//!
//! Trade-off: lane batches flush at every chunk boundary (a chunk's
//! partial must not depend on neighbouring chunks, or journaled
//! partials would not be recomputable). Under work-stealing this means
//! a claim grain smaller than the batch size yields short batches —
//! pick `grain ≥ batch` (the CLI default grain 1024 vs batch 256
//! already does); static schedules are unaffected (one chunk per
//! worker).

use super::batcher::BatchBuilder;
use super::engine::{CpuEngine, DetEngine, PrefixEngine};
use super::metrics::WorkerMetrics;
use crate::combin::{radic_sign, Chunk, CombinationStream, PascalTable, PrefixBlockStream};
use crate::linalg::{cofactors_exact, det_bareiss, NeumaierSum};
use crate::matrix::{MatF64, MatI64};
use crate::{Error, Result};
use std::time::Instant;

/// Reusable float-path lease executor.
pub struct LeaseRunner {
    inner: Inner,
}

enum Inner {
    /// Batched lane engine (cpu-lu or an XLA handle).
    Lanes {
        eng: Box<dyn DetEngine + Send>,
        builder: BatchBuilder,
    },
    /// Prefix-factored Laplace engine.
    Prefix { eng: PrefixEngine },
}

impl LeaseRunner {
    /// Wrap an arbitrary lane engine (batch geometry taken from it).
    pub fn lanes(eng: Box<dyn DetEngine + Send>) -> Self {
        let builder = BatchBuilder::new(eng.m(), eng.batch());
        Self { inner: Inner::Lanes { eng, builder } }
    }

    /// Pure-rust LU lane runner for `(m, batch)`.
    pub fn cpu(m: usize, batch: usize) -> Self {
        Self::lanes(Box::new(CpuEngine::new(m, batch.max(1))))
    }

    /// Prefix-factored runner for m-row jobs.
    pub fn prefix(m: usize) -> Self {
        Self { inner: Inner::Prefix { eng: PrefixEngine::new(m) } }
    }

    /// Engine label (metrics/CLI).
    pub fn label(&self) -> &'static str {
        match &self.inner {
            Inner::Lanes { eng, .. } => eng.label(),
            Inner::Prefix { .. } => "prefix",
        }
    }

    /// Evaluate the rank chunk to its signed partial sum.
    ///
    /// Deterministic: terms are accumulated in rank order (Neumaier) on
    /// this thread only, so equal inputs give equal bits.
    pub fn run_chunk(
        &mut self,
        a: &MatF64,
        table: &PascalTable,
        chunk: Chunk,
    ) -> Result<(f64, WorkerMetrics)> {
        let mut wm = WorkerMetrics::default();
        if chunk.len == 0 {
            return Ok((0.0, wm));
        }
        wm.chunks = 1;
        let value = match &mut self.inner {
            Inner::Lanes { eng, builder } => {
                run_chunk_lanes(eng, builder, a, table, chunk, &mut wm)?
            }
            Inner::Prefix { eng } => run_chunk_prefix(eng, a, table, chunk, &mut wm)?,
        };
        Ok((value, wm))
    }
}

fn flush_batch(
    builder: &mut BatchBuilder,
    eng: &mut Box<dyn DetEngine + Send>,
    acc: &mut NeumaierSum,
    wm: &mut WorkerMetrics,
) -> Result<()> {
    if builder.is_empty() {
        return Ok(());
    }
    let t0 = Instant::now();
    let partial = {
        // finalize() hands back disjoint field borrows (mutable subs
        // for in-place LU, shared signs).
        let (subs, signs, _) = builder.finalize();
        eng.run_batch(subs, signs)?
    };
    wm.engine_time += t0.elapsed();
    wm.batches += 1;
    acc.add(partial);
    builder.clear();
    Ok(())
}

fn run_chunk_lanes(
    eng: &mut Box<dyn DetEngine + Send>,
    builder: &mut BatchBuilder,
    a: &MatF64,
    table: &PascalTable,
    chunk: Chunk,
    wm: &mut WorkerMetrics,
) -> Result<f64> {
    builder.clear();
    let mut acc = NeumaierSum::new();
    let mut stream = CombinationStream::new(table, chunk.start, chunk.len)?;
    // Timing is chunk-granular: a per-term Instant::now() pair costs
    // more than the gather itself (EXPERIMENTS.md §Perf iteration 1).
    let mut t0 = Instant::now();
    while let Some(cols) = stream.next_ref() {
        builder.push(a, cols);
        wm.terms += 1;
        if builder.is_full() {
            wm.gather_time += t0.elapsed();
            flush_batch(builder, eng, &mut acc, wm)?;
            t0 = Instant::now();
        }
    }
    wm.gather_time += t0.elapsed();
    flush_batch(builder, eng, &mut acc, wm)?;
    Ok(acc.value())
}

fn run_chunk_prefix(
    eng: &mut PrefixEngine,
    a: &MatF64,
    table: &PascalTable,
    chunk: Chunk,
    wm: &mut WorkerMetrics,
) -> Result<f64> {
    let mut acc = NeumaierSum::new();
    let mut stream = PrefixBlockStream::new(table, chunk.start, chunk.len)?;
    let t0 = Instant::now();
    while let Some(b) = stream.next_block() {
        let out = eng.run_block(a, b.prefix, b.last_lo, b.last_hi);
        acc.add(out.partial);
        wm.terms += out.terms;
        wm.blocks += 1;
        if out.fell_back {
            wm.fallback_blocks += 1;
        }
    }
    wm.engine_time += t0.elapsed();
    Ok(acc.value())
}

/// Reusable exact-path (`i128`) lease executor.
pub struct ExactLeaseRunner {
    m: usize,
    use_prefix: bool,
    /// m×m gather scratch (per-term Bareiss path).
    scratch: Vec<i64>,
    /// m×(m−1) shared-prefix gather (prefix path).
    prefix_buf: Vec<i64>,
    /// Exact Laplace cofactors of the current prefix.
    cof: Vec<i128>,
    /// Minor scratch for [`cofactors_exact`].
    minor_buf: Vec<i64>,
}

impl ExactLeaseRunner {
    /// New runner for m-row jobs; `use_prefix` selects the exact prefix
    /// cofactor path over per-term Bareiss.
    pub fn new(m: usize, use_prefix: bool) -> Self {
        assert!(m >= 1);
        Self {
            m,
            use_prefix,
            scratch: vec![0i64; m * m],
            prefix_buf: vec![0i64; m * (m - 1)],
            cof: vec![0i128; m],
            minor_buf: Vec::new(),
        }
    }

    /// Engine label (metrics/CLI).
    pub fn label(&self) -> &'static str {
        if self.use_prefix {
            "exact-prefix"
        } else {
            "exact-bareiss"
        }
    }

    /// Evaluate the rank chunk to its exact signed partial (overflow-
    /// checked). Deterministic: integer addition is exact, so any
    /// grouping gives the same value; terms still run in rank order.
    pub fn run_chunk(
        &mut self,
        a: &MatI64,
        table: &PascalTable,
        chunk: Chunk,
    ) -> Result<(i128, WorkerMetrics)> {
        let mut wm = WorkerMetrics::default();
        if chunk.len == 0 {
            return Ok((0, wm));
        }
        wm.chunks = 1;
        let value = if self.use_prefix {
            self.run_chunk_prefix(a, table, chunk, &mut wm)?
        } else {
            self.run_chunk_bareiss(a, table, chunk, &mut wm)?
        };
        Ok((value, wm))
    }

    fn run_chunk_bareiss(
        &mut self,
        a: &MatI64,
        table: &PascalTable,
        chunk: Chunk,
        wm: &mut WorkerMetrics,
    ) -> Result<i128> {
        let m = self.m;
        let mut acc: i128 = 0;
        let mut stream = CombinationStream::new(table, chunk.start, chunk.len)?;
        let t0 = Instant::now();
        while let Some(cols) = stream.next_ref() {
            a.gather_cols_into(cols, &mut self.scratch);
            let det = det_bareiss(&self.scratch, m)?;
            let signed = if radic_sign(cols) > 0.0 { det } else { -det };
            acc = acc
                .checked_add(signed)
                .ok_or(Error::ExactOverflow("radic sum"))?;
            wm.terms += 1;
        }
        wm.engine_time += t0.elapsed();
        Ok(acc)
    }

    /// Exact prefix path: Bareiss-style integer cofactors shared per
    /// block, `i128` checked dot per sibling. No rank fallback is
    /// needed — exact arithmetic makes singular-prefix cofactors
    /// exactly zero.
    fn run_chunk_prefix(
        &mut self,
        a: &MatI64,
        table: &PascalTable,
        chunk: Chunk,
        wm: &mut WorkerMetrics,
    ) -> Result<i128> {
        let (m, n) = (self.m, a.cols());
        let r_const = (m as u64) * (m as u64 + 1) / 2;
        let mut acc: i128 = 0;
        let mut stream = PrefixBlockStream::new(table, chunk.start, chunk.len)?;
        let t0 = Instant::now();
        while let Some(b) = stream.next_block() {
            a.gather_cols_into(b.prefix, &mut self.prefix_buf);
            cofactors_exact(&self.prefix_buf, m, &mut self.minor_buf, &mut self.cof)?;
            let s_prefix: u64 = b.prefix.iter().map(|&c| c as u64).sum();
            let mut negative = (r_const + s_prefix + b.last_lo as u64) % 2 == 1;
            let data = a.data();
            for j in b.last_lo..=b.last_hi {
                let col = (j - 1) as usize;
                let mut det: i128 = 0;
                for (i, &c) in self.cof.iter().enumerate() {
                    let term = c
                        .checked_mul(data[i * n + col] as i128)
                        .ok_or(Error::ExactOverflow("prefix dot"))?;
                    det = det
                        .checked_add(term)
                        .ok_or(Error::ExactOverflow("prefix dot"))?;
                }
                let signed = if negative { -det } else { det };
                acc = acc
                    .checked_add(signed)
                    .ok_or(Error::ExactOverflow("radic sum"))?;
                negative = !negative;
                wm.terms += 1;
            }
            wm.blocks += 1;
        }
        wm.engine_time += t0.elapsed();
        Ok(acc)
    }
}

/// Borrowed lease input: the matrix plus (implicitly) the arithmetic
/// path a chunk must be evaluated on.
#[derive(Clone, Copy, Debug)]
pub enum LeaseMatrix<'a> {
    /// Float path.
    F64(&'a MatF64),
    /// Exact `i128` path.
    Exact(&'a MatI64),
}

/// A chunk's deterministic partial from either arithmetic path — the
/// coordinator-level twin of the jobs layer's `JobValue` (which adds
/// the wire/journal encoding on top).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LeasePartial {
    /// Float partial.
    F64(f64),
    /// Exact partial.
    Exact(i128),
}

/// The remote-lease adapter: one reusable executor covering the whole
/// engine matrix (float `cpu-lu`/`prefix`, exact Bareiss/prefix), so a
/// lease executor — the in-process jobs runner or a fleet worker that
/// only knows a job's spec tags — can run any chunk without matching on
/// engine families itself.
pub struct ChunkRunner {
    inner: AnyRunner,
}

enum AnyRunner {
    Float(LeaseRunner),
    Exact(ExactLeaseRunner),
}

impl ChunkRunner {
    /// Build the runner a job spec calls for: `exact` selects the
    /// `i128` path, `prefix` the prefix-factored engine over per-term
    /// lanes; `batch` only shapes the float lane engine.
    pub fn new(exact: bool, prefix: bool, m: usize, batch: usize) -> Self {
        let inner = if exact {
            AnyRunner::Exact(ExactLeaseRunner::new(m, prefix))
        } else if prefix {
            AnyRunner::Float(LeaseRunner::prefix(m))
        } else {
            AnyRunner::Float(LeaseRunner::cpu(m, batch))
        };
        Self { inner }
    }

    /// Engine label (metrics/CLI).
    pub fn label(&self) -> &'static str {
        match &self.inner {
            AnyRunner::Float(r) => r.label(),
            AnyRunner::Exact(r) => r.label(),
        }
    }

    /// Evaluate one rank chunk to its deterministic partial. Errors if
    /// the matrix's arithmetic path does not match the runner's.
    pub fn run_chunk(
        &mut self,
        a: LeaseMatrix<'_>,
        table: &PascalTable,
        chunk: Chunk,
    ) -> Result<(LeasePartial, WorkerMetrics)> {
        match (&mut self.inner, a) {
            (AnyRunner::Float(r), LeaseMatrix::F64(a)) => {
                let (v, wm) = r.run_chunk(a, table, chunk)?;
                Ok((LeasePartial::F64(v), wm))
            }
            (AnyRunner::Exact(r), LeaseMatrix::Exact(a)) => {
                let (v, wm) = r.run_chunk(a, table, chunk)?;
                Ok((LeasePartial::Exact(v), wm))
            }
            _ => Err(Error::Job("runner/payload mismatch".into())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::combin::combination_count;
    use crate::linalg::{radic_det_exact, radic_det_seq};
    use crate::matrix::gen;
    use crate::testkit::TestRng;

    fn chunks_of(total: u128, k: usize) -> Vec<Chunk> {
        crate::combin::partition_total(total, k)
    }

    #[test]
    fn lease_partials_sum_to_sequential() {
        let a = gen::uniform(&mut TestRng::from_seed(21), 3, 10, -1.0, 1.0);
        let seq = radic_det_seq(&a).unwrap();
        let table = PascalTable::new(10, 3).unwrap();
        let total = combination_count(10, 3).unwrap();
        let makers: [fn(usize) -> LeaseRunner; 2] =
            [|m| LeaseRunner::cpu(m, 16), LeaseRunner::prefix];
        for mk in makers {
            let mut runner = mk(3);
            let mut sum = NeumaierSum::new();
            let mut terms = 0u64;
            for c in chunks_of(total, 5) {
                let (v, wm) = runner.run_chunk(&a, &table, c).unwrap();
                sum.add(v);
                terms += wm.terms;
            }
            assert_eq!(terms as u128, total, "{}", runner.label());
            assert!(
                (sum.value() - seq).abs() < 1e-9 * seq.abs().max(1.0),
                "{}: {} vs {seq}",
                runner.label(),
                sum.value()
            );
        }
    }

    #[test]
    fn lease_is_bitwise_deterministic() {
        let a = gen::uniform(&mut TestRng::from_seed(22), 4, 11, -1.0, 1.0);
        let table = PascalTable::new(11, 4).unwrap();
        let chunk = Chunk { start: 37, len: 101 };
        let makers: [fn(usize) -> LeaseRunner; 2] =
            [|m| LeaseRunner::cpu(m, 8), LeaseRunner::prefix];
        for mk in makers {
            let (v1, _) = mk(4).run_chunk(&a, &table, chunk).unwrap();
            let (v2, _) = mk(4).run_chunk(&a, &table, chunk).unwrap();
            // A reused runner must agree with a fresh one.
            let mut reused = mk(4);
            reused
                .run_chunk(&a, &table, Chunk { start: 0, len: 19 })
                .unwrap();
            let (v3, _) = reused.run_chunk(&a, &table, chunk).unwrap();
            assert_eq!(v1.to_bits(), v2.to_bits());
            assert_eq!(v1.to_bits(), v3.to_bits());
        }
    }

    #[test]
    fn exact_lease_partials_sum_to_reference() {
        let a = gen::integer(&mut TestRng::from_seed(23), 3, 9, -6, 6);
        let want = radic_det_exact(&a).unwrap();
        let table = PascalTable::new(9, 3).unwrap();
        let total = combination_count(9, 3).unwrap();
        for use_prefix in [false, true] {
            let mut runner = ExactLeaseRunner::new(3, use_prefix);
            let mut acc: i128 = 0;
            for c in chunks_of(total, 4) {
                let (v, _) = runner.run_chunk(&a, &table, c).unwrap();
                acc += v;
            }
            assert_eq!(acc, want, "use_prefix={use_prefix}");
        }
    }

    #[test]
    fn chunk_runner_covers_engine_matrix_and_rejects_mismatch() {
        let af = gen::uniform(&mut TestRng::from_seed(25), 3, 9, -1.0, 1.0);
        let ai = gen::integer(&mut TestRng::from_seed(26), 3, 9, -6, 6);
        let table = PascalTable::new(9, 3).unwrap();
        let total = combination_count(9, 3).unwrap();
        let seq = radic_det_seq(&af).unwrap();
        let want = radic_det_exact(&ai).unwrap();
        for prefix in [false, true] {
            // Float family sums to the sequential reference.
            let mut fr = ChunkRunner::new(false, prefix, 3, 16);
            let mut sum = NeumaierSum::new();
            for c in chunks_of(total, 4) {
                match fr.run_chunk(LeaseMatrix::F64(&af), &table, c).unwrap() {
                    (LeasePartial::F64(v), _) => sum.add(v),
                    other => panic!("{other:?}"),
                }
            }
            assert!(
                (sum.value() - seq).abs() < 1e-9 * seq.abs().max(1.0),
                "{}",
                fr.label()
            );
            // Exact family sums to the exact reference.
            let mut er = ChunkRunner::new(true, prefix, 3, 16);
            let mut acc: i128 = 0;
            for c in chunks_of(total, 4) {
                match er.run_chunk(LeaseMatrix::Exact(&ai), &table, c).unwrap() {
                    (LeasePartial::Exact(v), _) => acc += v,
                    other => panic!("{other:?}"),
                }
            }
            assert_eq!(acc, want, "{}", er.label());
            // Path mismatch is an error, not a wrong answer.
            let c0 = Chunk { start: 0, len: 5 };
            assert!(fr.run_chunk(LeaseMatrix::Exact(&ai), &table, c0).is_err());
            assert!(er.run_chunk(LeaseMatrix::F64(&af), &table, c0).is_err());
        }
    }

    #[test]
    fn empty_chunk_is_identity() {
        let a = gen::uniform(&mut TestRng::from_seed(24), 2, 6, -1.0, 1.0);
        let table = PascalTable::new(6, 2).unwrap();
        let (v, wm) = LeaseRunner::prefix(2)
            .run_chunk(&a, &table, Chunk { start: 3, len: 0 })
            .unwrap();
        assert_eq!(v, 0.0);
        assert_eq!((wm.terms, wm.chunks), (0, 0));
    }
}
