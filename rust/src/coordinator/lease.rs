//! Chunk leases — one rank chunk evaluated to a *deterministic* partial,
//! generic over the scalar tower.
//!
//! A lease is the unit of restartable work: given the same matrix, the
//! same Pascal table and the same [`Chunk`], `run_chunk` always produces
//! the identical partial (bit-identical for `f64`, equal exact values
//! for the integer scalars), because every accumulation inside a chunk
//! happens in rank order on a single thread. The coordinator's worker
//! loops execute leases back-to-back in-process; the durable jobs
//! subsystem ([`crate::jobs`]) executes exactly the same leases but
//! journals each result, which is what makes an interrupted sweep
//! resumable without changing the final bits.
//!
//! One generic runner covers the whole engine matrix:
//!
//! * [`LeaseRunner<S>`](LeaseRunner) — the lease executor for any
//!   scalar `S` of the tower. Which machinery evaluates a chunk is the
//!   scalar family's choice ([`ScalarExec`]): `f64` plugs in the
//!   [`FloatEngine`] (lane batches over a [`DetEngine`], or the
//!   prefix-factored Laplace engine); the exact scalars (`i128`,
//!   [`BigInt`]) share one [`ExactEngine`] (per-term generic Bareiss,
//!   or generic prefix cofactors per sibling block).
//! * [`ChunkRunner`] — the dynamically-typed adapter over the three
//!   instantiations, for executors that only learn the scalar from a
//!   job spec's tags at runtime (the jobs runner, fleet workers).
//!
//! All scratch lives in the engine and is reused across leases, so the
//! steady-state hot path allocates nothing per chunk. That includes
//! the exact engines' elimination buffers: Bareiss working copies and
//! cofactor minors are hoisted per lease ([`CofactorScratch`],
//! `det_bareiss_in`) and recycled via `Scalar::assign_elem`, so even
//! `BigInt` limb vectors are reused across blocks and only *results*
//! still allocate (the price of unboundedness, measured in
//! `benches/bench_scalar.rs` §scratch).
//!
//! Overflow is a first-class outcome, not a wrong answer: a checked
//! scalar op that exceeds its range surfaces as
//! [`Error::ScalarOverflow`], and the runner stamps the failing chunk's
//! start rank into the error so an operator can name the offending
//! lease.
//!
//! Trade-off: lane batches flush at every chunk boundary (a chunk's
//! partial must not depend on neighbouring chunks, or journaled
//! partials would not be recomputable). Under work-stealing this means
//! a claim grain smaller than the batch size yields short batches —
//! pick `grain ≥ batch` (the CLI default grain 1024 vs batch 256
//! already does); static schedules are unaffected (one chunk per
//! worker).

use super::batcher::BatchBuilder;
use super::engine::{CpuEngine, DetEngine, PrefixEngine};
use super::metrics::WorkerMetrics;
use crate::combin::{radic_sign, Chunk, CombinationStream, PascalTable, PrefixBlockStream};
use crate::linalg::{
    cofactors_into, det_bareiss_in, CofactorScratch, KernelKind, NeumaierSum,
};
use crate::matrix::{Mat, MatF64, MatI64};
use crate::scalar::{BigInt, Scalar, ScalarKind};
use crate::{Error, Result};
use std::time::Instant;

/// Per-scalar chunk evaluation: how a [`LeaseRunner`] turns one rank
/// chunk into a partial. Implementations own all scratch and must be
/// deterministic (rank-ordered accumulation, single thread).
pub trait ChunkEngine<S: Scalar>: Send {
    /// Engine label (metrics/CLI).
    fn label(&self) -> &'static str;

    /// Evaluate a non-empty chunk into its signed partial, metering
    /// into `wm` (terms/blocks/timers; `chunks` is the runner's job).
    fn run_chunk(
        &mut self,
        a: &Mat<S::Elem>,
        table: &PascalTable,
        chunk: Chunk,
        wm: &mut WorkerMetrics,
    ) -> Result<S>;
}

/// Wires a scalar to the engine family that evaluates its chunks —
/// the one place the scalar → machinery choice lives.
pub trait ScalarExec: Scalar {
    /// The chunk engine this scalar family uses.
    type Engine: ChunkEngine<Self>;

    /// Build the engine for m-row jobs; `use_prefix` selects the
    /// prefix-factored path, `batch` shapes float lane engines only.
    fn engine(m: usize, use_prefix: bool, batch: usize) -> Self::Engine;
}

impl ScalarExec for f64 {
    type Engine = FloatEngine;

    fn engine(m: usize, use_prefix: bool, batch: usize) -> FloatEngine {
        if use_prefix {
            FloatEngine::prefix(m)
        } else {
            FloatEngine::cpu(m, batch)
        }
    }
}

impl ScalarExec for i128 {
    type Engine = ExactEngine<i128>;

    fn engine(m: usize, use_prefix: bool, _batch: usize) -> ExactEngine<i128> {
        ExactEngine::new(m, use_prefix)
    }
}

impl ScalarExec for BigInt {
    type Engine = ExactEngine<BigInt>;

    fn engine(m: usize, use_prefix: bool, _batch: usize) -> ExactEngine<BigInt> {
        ExactEngine::new(m, use_prefix)
    }
}

/// Reusable lease executor for scalar `S` — the one runner that
/// replaced the float/exact twin stacks.
pub struct LeaseRunner<S: ScalarExec> {
    eng: S::Engine,
}

impl<S: ScalarExec> LeaseRunner<S> {
    /// Runner for m-row jobs; `use_prefix` selects the prefix-factored
    /// engine, `batch` shapes float lane engines (ignored by exact
    /// scalars).
    pub fn new(m: usize, use_prefix: bool, batch: usize) -> Self {
        Self { eng: S::engine(m, use_prefix, batch) }
    }

    /// Engine label (metrics/CLI).
    pub fn label(&self) -> &'static str {
        self.eng.label()
    }

    /// Evaluate the rank chunk to its signed partial sum.
    ///
    /// Deterministic: terms are accumulated in rank order on this
    /// thread only (Neumaier for `f64`, exact addition otherwise), so
    /// equal inputs give equal partials. A scalar overflow inside the
    /// chunk comes back stamped with the chunk's start rank.
    pub fn run_chunk(
        &mut self,
        a: &Mat<S::Elem>,
        table: &PascalTable,
        chunk: Chunk,
    ) -> Result<(S, WorkerMetrics)> {
        let mut wm = WorkerMetrics::default();
        if chunk.len == 0 {
            return Ok((S::zero(), wm));
        }
        wm.chunks = 1;
        match self.eng.run_chunk(a, table, chunk, &mut wm) {
            Ok(value) => Ok((value, wm)),
            Err(Error::ScalarOverflow { what, chunk: None }) => {
                Err(Error::ScalarOverflow { what, chunk: Some(chunk.start) })
            }
            Err(e) => Err(e),
        }
    }
}

impl LeaseRunner<f64> {
    /// Wrap an arbitrary lane engine (batch geometry taken from it).
    pub fn lanes(eng: Box<dyn DetEngine + Send>) -> Self {
        Self { eng: FloatEngine::lanes(eng) }
    }

    /// Pure-rust LU lane runner for `(m, batch)`.
    pub fn cpu(m: usize, batch: usize) -> Self {
        Self { eng: FloatEngine::cpu(m, batch) }
    }

    /// Prefix-factored runner for m-row jobs (process-wide kernel).
    pub fn prefix(m: usize) -> Self {
        Self { eng: FloatEngine::prefix(m) }
    }

    /// Prefix-factored runner on an explicit dot kernel — the
    /// in-process escape hatch the kernel-equivalence and mixed-kernel
    /// fleet suites use (`RADDET_KERNEL` is read once per process).
    pub fn prefix_with_kernel(m: usize, kernel: KernelKind) -> Self {
        Self { eng: FloatEngine::prefix_with_kernel(m, kernel) }
    }

    /// The dot kernel of the prefix path (`None` for lane engines).
    pub fn float_kernel(&self) -> Option<KernelKind> {
        self.eng.float_kernel()
    }
}

/// The float chunk engine: batched lane evaluation (cpu-lu or an XLA
/// handle) or the prefix-factored Laplace engine.
pub struct FloatEngine {
    inner: FloatInner,
}

enum FloatInner {
    /// Batched lane engine (cpu-lu or an XLA handle).
    Lanes {
        eng: Box<dyn DetEngine + Send>,
        builder: BatchBuilder,
    },
    /// Prefix-factored Laplace engine.
    Prefix { eng: PrefixEngine },
}

impl FloatEngine {
    /// Wrap an arbitrary lane engine (batch geometry taken from it).
    pub fn lanes(eng: Box<dyn DetEngine + Send>) -> Self {
        let builder = BatchBuilder::new(eng.m(), eng.batch());
        Self { inner: FloatInner::Lanes { eng, builder } }
    }

    /// Pure-rust LU lane engine for `(m, batch)`.
    pub fn cpu(m: usize, batch: usize) -> Self {
        Self::lanes(Box::new(CpuEngine::new(m, batch.max(1))))
    }

    /// Prefix-factored engine for m-row jobs (process-wide kernel).
    pub fn prefix(m: usize) -> Self {
        Self { inner: FloatInner::Prefix { eng: PrefixEngine::new(m) } }
    }

    /// Prefix-factored engine on an explicit dot kernel.
    pub fn prefix_with_kernel(m: usize, kernel: KernelKind) -> Self {
        Self {
            inner: FloatInner::Prefix { eng: PrefixEngine::with_kernel(m, kernel) },
        }
    }

    /// The dot kernel of the prefix path (`None` for lane engines,
    /// whose hot loop is the per-lane LU, not the dispatched dot).
    pub fn float_kernel(&self) -> Option<KernelKind> {
        match &self.inner {
            FloatInner::Lanes { .. } => None,
            FloatInner::Prefix { eng } => Some(eng.kernel()),
        }
    }
}

impl ChunkEngine<f64> for FloatEngine {
    fn label(&self) -> &'static str {
        match &self.inner {
            FloatInner::Lanes { eng, .. } => eng.label(),
            FloatInner::Prefix { .. } => "prefix",
        }
    }

    fn run_chunk(
        &mut self,
        a: &MatF64,
        table: &PascalTable,
        chunk: Chunk,
        wm: &mut WorkerMetrics,
    ) -> Result<f64> {
        match &mut self.inner {
            FloatInner::Lanes { eng, builder } => {
                run_chunk_lanes(eng, builder, a, table, chunk, wm)
            }
            FloatInner::Prefix { eng } => run_chunk_prefix(eng, a, table, chunk, wm),
        }
    }
}

fn flush_batch(
    builder: &mut BatchBuilder,
    eng: &mut Box<dyn DetEngine + Send>,
    acc: &mut NeumaierSum,
    wm: &mut WorkerMetrics,
) -> Result<()> {
    if builder.is_empty() {
        return Ok(());
    }
    let t0 = Instant::now();
    let partial = {
        // finalize() hands back disjoint field borrows (mutable subs
        // for in-place LU, shared signs).
        let (subs, signs, _) = builder.finalize();
        eng.run_batch(subs, signs)?
    };
    wm.engine_time += t0.elapsed();
    wm.batches += 1;
    acc.add(partial);
    builder.clear();
    Ok(())
}

fn run_chunk_lanes(
    eng: &mut Box<dyn DetEngine + Send>,
    builder: &mut BatchBuilder,
    a: &MatF64,
    table: &PascalTable,
    chunk: Chunk,
    wm: &mut WorkerMetrics,
) -> Result<f64> {
    builder.clear();
    let mut acc = NeumaierSum::new();
    let mut stream = CombinationStream::new(table, chunk.start, chunk.len)?;
    // Timing is chunk-granular: a per-term Instant::now() pair costs
    // more than the gather itself (EXPERIMENTS.md §Perf iteration 1).
    let mut t0 = Instant::now();
    while let Some(cols) = stream.next_ref() {
        builder.push(a, cols);
        wm.terms += 1;
        if builder.is_full() {
            wm.gather_time += t0.elapsed();
            flush_batch(builder, eng, &mut acc, wm)?;
            t0 = Instant::now();
        }
    }
    wm.gather_time += t0.elapsed();
    flush_batch(builder, eng, &mut acc, wm)?;
    Ok(acc.value())
}

fn run_chunk_prefix(
    eng: &mut PrefixEngine,
    a: &MatF64,
    table: &PascalTable,
    chunk: Chunk,
    wm: &mut WorkerMetrics,
) -> Result<f64> {
    let mut acc = NeumaierSum::new();
    let mut stream = PrefixBlockStream::new(table, chunk.start, chunk.len)?;
    let t0 = Instant::now();
    while let Some(b) = stream.next_block() {
        let out = eng.run_block(a, b.prefix, b.last_lo, b.last_hi);
        acc.add(out.partial);
        wm.terms += out.terms;
        wm.blocks += 1;
        if out.fell_back {
            wm.fallback_blocks += 1;
        }
    }
    wm.engine_time += t0.elapsed();
    Ok(acc.value())
}

/// The exact chunk engine, shared by every integer scalar of the
/// tower: per-term generic Bareiss lanes, or generic prefix cofactors
/// shared per sibling block. No rank fallback is needed on the prefix
/// path — exact arithmetic makes singular-prefix cofactors exactly
/// zero.
pub struct ExactEngine<S: Scalar<Elem = i64>> {
    m: usize,
    use_prefix: bool,
    /// m×m gather scratch (per-term Bareiss path).
    scratch: Vec<i64>,
    /// m×(m−1) shared-prefix gather (prefix path).
    prefix_buf: Vec<i64>,
    /// Exact Laplace cofactors of the current prefix.
    cof: Vec<S>,
    /// Cofactor scratch (minor gather + Bareiss elimination copy),
    /// hoisted per lease so `BigInt` limb buffers survive across
    /// blocks instead of being reallocated per minor.
    cof_scratch: CofactorScratch<S>,
    /// Bareiss elimination copy for the per-term path, same rationale.
    elim_buf: Vec<S>,
    /// One reused element lift for the prefix dot (`assign_elem`
    /// instead of a fresh `from_elem` per matrix entry).
    elem_buf: S,
}

impl<S: Scalar<Elem = i64>> ExactEngine<S> {
    /// New engine for m-row jobs; `use_prefix` selects the prefix
    /// cofactor path over per-term Bareiss.
    pub fn new(m: usize, use_prefix: bool) -> Self {
        assert!(m >= 1);
        Self {
            m,
            use_prefix,
            scratch: vec![0i64; m * m],
            prefix_buf: vec![0i64; m * (m - 1)],
            cof: vec![S::zero(); m],
            cof_scratch: CofactorScratch::new(),
            elim_buf: Vec::new(),
            elem_buf: S::zero(),
        }
    }

    fn run_chunk_bareiss(
        &mut self,
        a: &MatI64,
        table: &PascalTable,
        chunk: Chunk,
        wm: &mut WorkerMetrics,
    ) -> Result<S> {
        let m = self.m;
        let mut acc = S::accum_new();
        let mut stream = CombinationStream::new(table, chunk.start, chunk.len)?;
        let t0 = Instant::now();
        while let Some(cols) = stream.next_ref() {
            a.gather_cols_into(cols, &mut self.scratch);
            let det: S = det_bareiss_in(&self.scratch, m, &mut self.elim_buf)?;
            let signed = if radic_sign(cols) > 0.0 {
                det
            } else {
                det.neg_checked("radic sum")?
            };
            S::accum_add(&mut acc, &signed, "radic sum")?;
            wm.terms += 1;
        }
        wm.engine_time += t0.elapsed();
        Ok(S::accum_value(&acc))
    }

    /// Exact prefix path: integer cofactors shared per block, a checked
    /// scalar dot per sibling.
    fn run_chunk_prefix(
        &mut self,
        a: &MatI64,
        table: &PascalTable,
        chunk: Chunk,
        wm: &mut WorkerMetrics,
    ) -> Result<S> {
        let (m, n) = (self.m, a.cols());
        let r_const = (m as u64) * (m as u64 + 1) / 2;
        let mut acc = S::accum_new();
        let mut stream = PrefixBlockStream::new(table, chunk.start, chunk.len)?;
        let t0 = Instant::now();
        while let Some(b) = stream.next_block() {
            a.gather_cols_into(b.prefix, &mut self.prefix_buf);
            cofactors_into(&self.prefix_buf, m, &mut self.cof_scratch, &mut self.cof)?;
            let s_prefix: u64 = b.prefix.iter().map(|&c| c as u64).sum();
            let mut negative = (r_const + s_prefix + b.last_lo as u64) % 2 == 1;
            let data = a.data();
            for j in b.last_lo..=b.last_hi {
                let col = (j - 1) as usize;
                let mut det = S::zero();
                for (i, c) in self.cof.iter().enumerate() {
                    self.elem_buf.assign_elem(data[i * n + col]);
                    let term = c.mul_checked(&self.elem_buf, "prefix dot")?;
                    det = det.add_checked(&term, "prefix dot")?;
                }
                let signed = if negative { det.neg_checked("radic sum")? } else { det };
                S::accum_add(&mut acc, &signed, "radic sum")?;
                negative = !negative;
                wm.terms += 1;
            }
            wm.blocks += 1;
        }
        wm.engine_time += t0.elapsed();
        Ok(S::accum_value(&acc))
    }
}

impl<S: Scalar<Elem = i64>> ChunkEngine<S> for ExactEngine<S> {
    fn label(&self) -> &'static str {
        match (S::KIND, self.use_prefix) {
            (ScalarKind::Big, false) => "big-bareiss",
            (ScalarKind::Big, true) => "big-prefix",
            (_, false) => "exact-bareiss",
            (_, true) => "exact-prefix",
        }
    }

    fn run_chunk(
        &mut self,
        a: &MatI64,
        table: &PascalTable,
        chunk: Chunk,
        wm: &mut WorkerMetrics,
    ) -> Result<S> {
        if self.use_prefix {
            self.run_chunk_prefix(a, table, chunk, wm)
        } else {
            self.run_chunk_bareiss(a, table, chunk, wm)
        }
    }
}

/// Borrowed lease input: the matrix plus (implicitly) the element type
/// a chunk must be evaluated on. Both integer scalars share the
/// [`LeaseMatrix::Exact`] payload — the *scalar* arithmetic is the
/// runner's axis, the *elements* are `i64` either way.
#[derive(Clone, Copy, Debug)]
pub enum LeaseMatrix<'a> {
    /// Float path.
    F64(&'a MatF64),
    /// Integer payload (checked `i128` or `BigInt` arithmetic).
    Exact(&'a MatI64),
}

/// A chunk's deterministic partial from any scalar of the tower — the
/// coordinator-level twin of the jobs layer's `JobValue` (which adds
/// the wire/journal encoding on top).
#[derive(Clone, Debug, PartialEq)]
pub enum LeasePartial {
    /// Float partial.
    F64(f64),
    /// Checked-`i128` partial.
    Exact(i128),
    /// Big-integer partial.
    Big(BigInt),
}

/// The remote-lease adapter: the three [`LeaseRunner`] instantiations
/// behind one dynamically-tagged face, so a lease executor — the
/// in-process jobs runner or a fleet worker that only knows a job's
/// spec tags — can run any chunk without matching on scalar families
/// itself.
pub enum ChunkRunner {
    /// Float engines (`cpu-lu` lanes / `prefix`).
    F64(LeaseRunner<f64>),
    /// Checked `i128` engines (`exact-bareiss` / `exact-prefix`).
    I128(LeaseRunner<i128>),
    /// Big-integer engines (`big-bareiss` / `big-prefix`).
    Big(LeaseRunner<BigInt>),
}

impl ChunkRunner {
    /// Build the runner a job spec calls for: `scalar` picks the
    /// arithmetic, `use_prefix` the prefix-factored engine over
    /// per-term lanes; `batch` only shapes the float lane engine.
    pub fn new(scalar: ScalarKind, use_prefix: bool, m: usize, batch: usize) -> Self {
        match scalar {
            ScalarKind::F64 => ChunkRunner::F64(LeaseRunner::new(m, use_prefix, batch)),
            ScalarKind::I128 => ChunkRunner::I128(LeaseRunner::new(m, use_prefix, batch)),
            ScalarKind::Big => ChunkRunner::Big(LeaseRunner::new(m, use_prefix, batch)),
        }
    }

    /// [`ChunkRunner::new`] with an explicit float dot kernel. Only
    /// the f64 prefix engine dispatches kernels; every other
    /// scalar/engine combination ignores the hint (their hot loops are
    /// exact arithmetic or per-lane LU).
    pub fn with_kernel(
        scalar: ScalarKind,
        use_prefix: bool,
        m: usize,
        batch: usize,
        kernel: KernelKind,
    ) -> Self {
        if scalar == ScalarKind::F64 && use_prefix {
            ChunkRunner::F64(LeaseRunner::prefix_with_kernel(m, kernel))
        } else {
            Self::new(scalar, use_prefix, m, batch)
        }
    }

    /// Engine label (metrics/CLI).
    pub fn label(&self) -> &'static str {
        match self {
            ChunkRunner::F64(r) => r.label(),
            ChunkRunner::I128(r) => r.label(),
            ChunkRunner::Big(r) => r.label(),
        }
    }

    /// The active float dot kernel, when this runner has one (f64
    /// prefix engine only) — what the jobs manager meters as
    /// `kernel_<name>_blocks_total`.
    pub fn float_kernel(&self) -> Option<KernelKind> {
        match self {
            ChunkRunner::F64(r) => r.float_kernel(),
            _ => None,
        }
    }

    /// The scalar this runner evaluates in.
    pub fn scalar(&self) -> ScalarKind {
        match self {
            ChunkRunner::F64(_) => ScalarKind::F64,
            ChunkRunner::I128(_) => ScalarKind::I128,
            ChunkRunner::Big(_) => ScalarKind::Big,
        }
    }

    /// Evaluate one rank chunk to its deterministic partial. Errors if
    /// the matrix's element type does not match the runner's scalar.
    pub fn run_chunk(
        &mut self,
        a: LeaseMatrix<'_>,
        table: &PascalTable,
        chunk: Chunk,
    ) -> Result<(LeasePartial, WorkerMetrics)> {
        match (self, a) {
            (ChunkRunner::F64(r), LeaseMatrix::F64(a)) => {
                let (v, wm) = r.run_chunk(a, table, chunk)?;
                Ok((LeasePartial::F64(v), wm))
            }
            (ChunkRunner::I128(r), LeaseMatrix::Exact(a)) => {
                let (v, wm) = r.run_chunk(a, table, chunk)?;
                Ok((LeasePartial::Exact(v), wm))
            }
            (ChunkRunner::Big(r), LeaseMatrix::Exact(a)) => {
                let (v, wm) = r.run_chunk(a, table, chunk)?;
                Ok((LeasePartial::Big(v), wm))
            }
            _ => Err(Error::Job("runner/payload mismatch".into())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::combin::combination_count;
    use crate::linalg::{radic_det_exact, radic_det_generic, radic_det_seq};
    use crate::matrix::gen;
    use crate::testkit::TestRng;

    fn chunks_of(total: u128, k: usize) -> Vec<Chunk> {
        crate::combin::partition_total(total, k)
    }

    #[test]
    fn lease_partials_sum_to_sequential() {
        let a = gen::uniform(&mut TestRng::from_seed(21), 3, 10, -1.0, 1.0);
        let seq = radic_det_seq(&a).unwrap();
        let table = PascalTable::new(10, 3).unwrap();
        let total = combination_count(10, 3).unwrap();
        let makers: [fn(usize) -> LeaseRunner<f64>; 2] =
            [|m| LeaseRunner::cpu(m, 16), LeaseRunner::prefix];
        for mk in makers {
            let mut runner = mk(3);
            let mut sum = NeumaierSum::new();
            let mut terms = 0u64;
            for c in chunks_of(total, 5) {
                let (v, wm) = runner.run_chunk(&a, &table, c).unwrap();
                sum.add(v);
                terms += wm.terms;
            }
            assert_eq!(terms as u128, total, "{}", runner.label());
            assert!(
                (sum.value() - seq).abs() < 1e-9 * seq.abs().max(1.0),
                "{}: {} vs {seq}",
                runner.label(),
                sum.value()
            );
        }
    }

    #[test]
    fn lease_is_bitwise_deterministic() {
        let a = gen::uniform(&mut TestRng::from_seed(22), 4, 11, -1.0, 1.0);
        let table = PascalTable::new(11, 4).unwrap();
        let chunk = Chunk { start: 37, len: 101 };
        let makers: [fn(usize) -> LeaseRunner<f64>; 2] =
            [|m| LeaseRunner::cpu(m, 8), LeaseRunner::prefix];
        for mk in makers {
            let (v1, _) = mk(4).run_chunk(&a, &table, chunk).unwrap();
            let (v2, _) = mk(4).run_chunk(&a, &table, chunk).unwrap();
            // A reused runner must agree with a fresh one.
            let mut reused = mk(4);
            reused
                .run_chunk(&a, &table, Chunk { start: 0, len: 19 })
                .unwrap();
            let (v3, _) = reused.run_chunk(&a, &table, chunk).unwrap();
            assert_eq!(v1.to_bits(), v2.to_bits());
            assert_eq!(v1.to_bits(), v3.to_bits());
        }
    }

    #[test]
    fn exact_lease_partials_sum_to_reference() {
        let a = gen::integer(&mut TestRng::from_seed(23), 3, 9, -6, 6);
        let want = radic_det_exact(&a).unwrap();
        let table = PascalTable::new(9, 3).unwrap();
        let total = combination_count(9, 3).unwrap();
        for use_prefix in [false, true] {
            let mut runner = LeaseRunner::<i128>::new(3, use_prefix, 0);
            let mut acc: i128 = 0;
            for c in chunks_of(total, 4) {
                let (v, _) = runner.run_chunk(&a, &table, c).unwrap();
                acc += v;
            }
            assert_eq!(acc, want, "use_prefix={use_prefix}");
        }
    }

    #[test]
    fn bigint_lease_partials_sum_to_reference() {
        let a = gen::integer(&mut TestRng::from_seed(27), 3, 9, -6, 6);
        let want: BigInt = radic_det_generic(&a).unwrap();
        let table = PascalTable::new(9, 3).unwrap();
        let total = combination_count(9, 3).unwrap();
        for use_prefix in [false, true] {
            let mut runner = LeaseRunner::<BigInt>::new(3, use_prefix, 0);
            let mut acc = BigInt::zero();
            let mut terms = 0u64;
            for c in chunks_of(total, 4) {
                let (v, wm) = runner.run_chunk(&a, &table, c).unwrap();
                acc = acc.add_checked(&v, "test").unwrap();
                terms += wm.terms;
            }
            assert_eq!(acc, want, "{}", runner.label());
            assert_eq!(terms as u128, total);
        }
    }

    #[test]
    fn overflow_error_names_the_chunk() {
        // Entries ~9e8 with m=6: any chunk's Bareiss intermediates
        // blow past i128; the error must carry the chunk's start rank.
        let a = gen::integer(
            &mut TestRng::from_seed(28),
            6,
            8,
            -900_000_000,
            900_000_000,
        );
        let table = PascalTable::new(8, 6).unwrap();
        for use_prefix in [false, true] {
            let mut runner = LeaseRunner::<i128>::new(6, use_prefix, 0);
            let err = runner
                .run_chunk(&a, &table, Chunk { start: 7, len: 5 })
                .unwrap_err();
            match err {
                Error::ScalarOverflow { chunk: Some(start), .. } => assert_eq!(start, 7),
                other => panic!("expected chunk-stamped overflow, got {other}"),
            }
            // The identical chunk computes fine in BigInt.
            let mut wide = LeaseRunner::<BigInt>::new(6, use_prefix, 0);
            let (v, _) = wide.run_chunk(&a, &table, Chunk { start: 7, len: 5 }).unwrap();
            assert!(!v.is_zero());
        }
    }

    #[test]
    fn chunk_runner_covers_engine_matrix_and_rejects_mismatch() {
        let af = gen::uniform(&mut TestRng::from_seed(25), 3, 9, -1.0, 1.0);
        let ai = gen::integer(&mut TestRng::from_seed(26), 3, 9, -6, 6);
        let table = PascalTable::new(9, 3).unwrap();
        let total = combination_count(9, 3).unwrap();
        let seq = radic_det_seq(&af).unwrap();
        let want = radic_det_exact(&ai).unwrap();
        for prefix in [false, true] {
            // Float family sums to the sequential reference.
            let mut fr = ChunkRunner::new(ScalarKind::F64, prefix, 3, 16);
            let mut sum = NeumaierSum::new();
            for c in chunks_of(total, 4) {
                match fr.run_chunk(LeaseMatrix::F64(&af), &table, c).unwrap() {
                    (LeasePartial::F64(v), _) => sum.add(v),
                    other => panic!("{other:?}"),
                }
            }
            assert!(
                (sum.value() - seq).abs() < 1e-9 * seq.abs().max(1.0),
                "{}",
                fr.label()
            );
            // Both exact families sum to the exact reference.
            let mut er = ChunkRunner::new(ScalarKind::I128, prefix, 3, 16);
            let mut acc: i128 = 0;
            for c in chunks_of(total, 4) {
                match er.run_chunk(LeaseMatrix::Exact(&ai), &table, c).unwrap() {
                    (LeasePartial::Exact(v), _) => acc += v,
                    other => panic!("{other:?}"),
                }
            }
            assert_eq!(acc, want, "{}", er.label());
            let mut br = ChunkRunner::new(ScalarKind::Big, prefix, 3, 16);
            let mut big_acc = BigInt::zero();
            for c in chunks_of(total, 4) {
                match br.run_chunk(LeaseMatrix::Exact(&ai), &table, c).unwrap() {
                    (LeasePartial::Big(v), _) => {
                        big_acc = big_acc.add_checked(&v, "test").unwrap()
                    }
                    other => panic!("{other:?}"),
                }
            }
            assert_eq!(big_acc, BigInt::from_i128(want), "{}", br.label());
            // Path mismatch is an error, not a wrong answer.
            let c0 = Chunk { start: 0, len: 5 };
            assert!(fr.run_chunk(LeaseMatrix::Exact(&ai), &table, c0).is_err());
            assert!(er.run_chunk(LeaseMatrix::F64(&af), &table, c0).is_err());
            assert!(br.run_chunk(LeaseMatrix::F64(&af), &table, c0).is_err());
        }
    }

    #[test]
    fn empty_chunk_is_identity() {
        let a = gen::uniform(&mut TestRng::from_seed(24), 2, 6, -1.0, 1.0);
        let table = PascalTable::new(6, 2).unwrap();
        let (v, wm) = LeaseRunner::prefix(2)
            .run_chunk(&a, &table, Chunk { start: 3, len: 0 })
            .unwrap();
        assert_eq!(v, 0.0);
        assert_eq!((wm.terms, wm.chunks), (0, 0));
    }
}
