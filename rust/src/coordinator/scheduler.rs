//! Work distribution across workers.
//!
//! * [`Schedule::Static`] — the paper's §5 granularity: `C(n,m)/k`
//!   contiguous ranks per processor, fixed up front.
//! * [`Schedule::WorkStealing`] — an ablation the paper doesn't have:
//!   workers claim fixed-size rank blocks from a shared atomic cursor,
//!   which rides out load imbalance (e.g. one worker descheduled) at the
//!   cost of one atomic RMW per block. `benches/bench_scaling.rs`
//!   compares the two.

use crate::combin::{
    block_aligned_grain, partition_total, partition_total_block_aligned, Chunk, PascalTable,
};
use crate::Result;
use std::sync::atomic::{AtomicU64, Ordering};

/// Scheduling policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// One contiguous chunk per worker (paper §5).
    Static,
    /// Shared cursor over blocks of `grain` ranks.
    WorkStealing {
        /// Ranks claimed per cursor increment (typically a few batches).
        grain: u64,
    },
}

impl Default for Schedule {
    fn default() -> Self {
        Schedule::Static
    }
}

/// A source of rank chunks for one worker.
pub enum WorkSource<'a> {
    /// The worker's single static chunk.
    Fixed(Option<Chunk>),
    /// Shared-cursor claimer.
    Stealing { cursor: &'a AtomicU64, total: u64, grain: u64 },
}

impl WorkSource<'_> {
    /// Claim the next chunk, or `None` when the job is exhausted.
    pub fn next_chunk(&mut self) -> Option<Chunk> {
        match self {
            WorkSource::Fixed(slot) => slot.take().filter(|c| c.len > 0),
            WorkSource::Stealing { cursor, total, grain } => {
                let start = cursor.fetch_add(*grain, Ordering::Relaxed);
                if start >= *total {
                    return None;
                }
                let len = (*grain).min(*total - start);
                Some(Chunk { start: start as u128, len: len as u128 })
            }
        }
    }
}

/// Per-job scheduler state shared by all workers.
pub struct JobSchedule {
    schedule: Schedule,
    chunks: Vec<Chunk>,
    cursor: AtomicU64,
    total: u64,
}

impl JobSchedule {
    /// Plan a job of `total` ranks over `workers` workers.
    ///
    /// `total` must fit u64 for work-stealing (the coordinator's term
    /// cap guarantees this long before the cursor would saturate).
    pub fn new(schedule: Schedule, total: u128, workers: usize) -> Self {
        let chunks = match schedule {
            Schedule::Static => partition_total(total, workers),
            Schedule::WorkStealing { .. } => Vec::new(),
        };
        Self {
            schedule,
            chunks,
            cursor: AtomicU64::new(0),
            total: u64::try_from(total).expect("term cap keeps totals in u64"),
        }
    }

    /// Plan a job with chunk boundaries aligned to sibling-block starts
    /// — the prefix engine's schedule. Static chunks are snapped to
    /// block starts ([`partition_total_block_aligned`], the same shared
    /// implementation the durable jobs subsystem plans with), so no
    /// worker ever splits (and re-factorizes) another worker's block;
    /// the stealing grain is rounded up to whole-block multiples
    /// ([`block_aligned_grain`]) so at most the first/last block of a
    /// claim is truncated.
    pub fn new_block_aligned(
        schedule: Schedule,
        total: u128,
        workers: usize,
        table: &PascalTable,
    ) -> Result<Self> {
        let (schedule, chunks) = match schedule {
            Schedule::Static => (
                schedule,
                partition_total_block_aligned(total, workers, table)?,
            ),
            Schedule::WorkStealing { grain } => (
                Schedule::WorkStealing {
                    grain: block_aligned_grain(grain, table.n(), table.m()),
                },
                Vec::new(),
            ),
        };
        Ok(Self {
            schedule,
            chunks,
            cursor: AtomicU64::new(0),
            total: u64::try_from(total).expect("term cap keeps totals in u64"),
        })
    }

    /// The work source for worker `w`.
    pub fn source(&self, w: usize) -> WorkSource<'_> {
        match self.schedule {
            Schedule::Static => WorkSource::Fixed(self.chunks.get(w).copied()),
            Schedule::WorkStealing { grain } => WorkSource::Stealing {
                cursor: &self.cursor,
                total: self.total,
                grain: grain.max(1),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(mut src: WorkSource<'_>) -> Vec<Chunk> {
        let mut v = Vec::new();
        while let Some(c) = src.next_chunk() {
            v.push(c);
        }
        v
    }

    #[test]
    fn static_one_chunk_per_worker() {
        let js = JobSchedule::new(Schedule::Static, 10, 3);
        let all: Vec<Chunk> = (0..3).flat_map(|w| drain(js.source(w))).collect();
        let covered: u128 = all.iter().map(|c| c.len).sum();
        assert_eq!(covered, 10);
        assert_eq!(all.len(), 3);
    }

    #[test]
    fn static_extra_workers_idle() {
        let js = JobSchedule::new(Schedule::Static, 2, 5);
        let nonempty = (0..5).filter(|&w| !drain(js.source(w)).is_empty()).count();
        assert_eq!(nonempty, 2);
    }

    #[test]
    fn block_aligned_static_tiles_and_starts_on_blocks() {
        // C(10,4) = 210 over 4 workers.
        let table = PascalTable::new(10, 4).unwrap();
        let js = JobSchedule::new_block_aligned(Schedule::Static, 210, 4, &table).unwrap();
        let mut all: Vec<Chunk> = (0..4).flat_map(|w| drain(js.source(w))).collect();
        all.sort_by_key(|c| c.start);
        let mut cursor = 0u128;
        for c in &all {
            assert_eq!(c.start, cursor);
            cursor = c.end();
            assert_eq!(
                crate::combin::block_start(&table, c.start).unwrap(),
                c.start,
                "chunk must start on a block boundary"
            );
        }
        assert_eq!(cursor, 210);
    }

    #[test]
    fn block_aligned_stealing_rounds_grain() {
        // n=10, m=4 ⇒ max block width 7; grain 10 rounds to 14.
        let table = PascalTable::new(10, 4).unwrap();
        let js = JobSchedule::new_block_aligned(
            Schedule::WorkStealing { grain: 10 },
            210,
            3,
            &table,
        )
        .unwrap();
        let first = drain(js.source(0));
        assert_eq!(first[0].len, 14, "grain snapped to a whole-block multiple");
    }

    #[test]
    fn stealing_covers_exactly_once() {
        let js = JobSchedule::new(Schedule::WorkStealing { grain: 3 }, 10, 4);
        // Sequentially drain from several sources; chunks must tile [0,10).
        let mut all: Vec<Chunk> = (0..4).flat_map(|w| drain(js.source(w))).collect();
        all.sort_by_key(|c| c.start);
        let mut cursor = 0u128;
        for c in &all {
            assert_eq!(c.start, cursor);
            cursor = c.end();
        }
        assert_eq!(cursor, 10);
    }

    #[test]
    fn stealing_concurrent_no_overlap() {
        let js = std::sync::Arc::new(JobSchedule::new(
            Schedule::WorkStealing { grain: 7 },
            100_000,
            8,
        ));
        let mut handles = Vec::new();
        for w in 0..8 {
            let js = std::sync::Arc::clone(&js);
            handles.push(std::thread::spawn(move || {
                let mut src = js.source(w);
                let mut claimed = Vec::new();
                while let Some(c) = src.next_chunk() {
                    claimed.push(c);
                }
                claimed
            }));
        }
        let mut all: Vec<Chunk> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort_by_key(|c| c.start);
        let mut cursor = 0u128;
        for c in &all {
            assert_eq!(c.start, cursor, "overlap/gap at {cursor}");
            cursor = c.end();
        }
        assert_eq!(cursor, 100_000);
    }
}
