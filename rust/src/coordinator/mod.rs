//! L3 coordinator — the paper's §5 parallel algorithm as a system.
//!
//! A job (`radic_det`) is planned as `C(n,m)` dictionary-order ranks,
//! partitioned across workers ([`scheduler`]), each of which unranks its
//! chunk start once ([`crate::combin::CombinationStream`]), gathers
//! column-submatrices + Radić signs into fixed batches ([`batcher`]),
//! and evaluates them on a pluggable engine ([`engine`]): pure-rust LU
//! or the AOT-compiled JAX/Pallas graph via PJRT ([`dispatch`]).
//! Worker partial sums are Neumaier-compensated and merged
//! deterministically in worker order.
//!
//! [`EngineKind::Prefix`] swaps the per-term O(m³) gather+LU loop for
//! the prefix-factored path: block-aligned chunks
//! ([`JobSchedule::new_block_aligned`]), sibling blocks
//! ([`crate::combin::PrefixBlockStream`]), one m×(m−1) factorization
//! per block ([`crate::linalg::MinorsWorkspace`]) and an O(m) Laplace
//! dot per term — amortized O(m³/w + m) per term for width-w blocks.

pub mod batcher;
pub mod dispatch;
pub mod engine;
pub mod lease;
pub mod metrics;
pub mod scheduler;

pub use batcher::BatchBuilder;
pub use engine::{BlockOutcome, CpuEngine, DetEngine, PrefixEngine};
pub use lease::{
    ChunkEngine, ChunkRunner, ExactEngine, FloatEngine, LeaseMatrix, LeasePartial, LeaseRunner,
    ScalarExec,
};
pub use metrics::{JobMetrics, WorkerMetrics};
pub use scheduler::{JobSchedule, Schedule};

use crate::combin::{combination_count, PascalTable};
use crate::linalg::NeumaierSum;
use crate::matrix::{MatF64, MatI64};
use crate::runtime::{resolve_artifact_dir, Dtype, Manifest};
use crate::scalar::{BigInt, Scalar};
use crate::{Error, Result};
use std::path::PathBuf;
use std::time::Instant;

/// Which determinant engine evaluates batches.
///
/// This is the *evaluation-family* axis of the engine matrix; the
/// orthogonal *scalar* axis ([`crate::scalar::ScalarKind`] — `f64`,
/// checked `i128`, `BigInt`) is chosen by which entry point runs the
/// job: [`Coordinator::radic_det`] (f64),
/// [`Coordinator::radic_det_scalar`] and its `exact`/`big` wrappers,
/// or — for durable jobs — the payload tag a
/// [`crate::jobs::JobSpec`] carries. Every family serves every scalar
/// through the one generic [`LeaseRunner`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// XLA if an artifact bucket exists for `m`, else CPU.
    Auto,
    /// Pure-rust LU.
    Cpu,
    /// AOT JAX/Pallas graph via PJRT (requires `make artifacts`).
    Xla,
    /// Prefix-factored Laplace engine: factorize each sibling block's
    /// shared m×(m−1) prefix once, O(m) per term thereafter
    /// ([`PrefixEngine`]). Block-aligned scheduling, explicit LU
    /// fallback on rank-deficient prefixes. On the float path the
    /// per-sibling dots run on a runtime-dispatched SIMD kernel
    /// ([`crate::linalg::KernelKind`]; force one with
    /// `RADDET_KERNEL=scalar|unrolled|avx2|neon`) — all kernels are
    /// bit-identical, so this changes speed, never bits.
    Prefix,
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker threads (0 ⇒ available parallelism).
    pub workers: usize,
    /// Preferred batch size (the XLA engine snaps to the closest
    /// artifact bucket ≤ this).
    pub batch: usize,
    /// Engine selection.
    pub engine: EngineKind,
    /// Scheduling policy.
    pub schedule: Schedule,
    /// Artifact directory override.
    pub artifact_dir: Option<PathBuf>,
    /// XLA executor threads (PJRT sessions).
    pub xla_executors: usize,
    /// Refuse jobs with more terms than this.
    pub term_cap: u128,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            workers: 0,
            batch: 256,
            engine: EngineKind::Auto,
            schedule: Schedule::Static,
            artifact_dir: None,
            xla_executors: 2,
            term_cap: 1 << 36,
        }
    }
}

/// Result of one Radić determinant job.
#[derive(Clone, Debug)]
pub struct RadicOutput {
    /// The determinant.
    pub det: f64,
    /// Number of Radić terms evaluated.
    pub terms: u128,
    /// Engine label actually used.
    pub engine: &'static str,
    /// Aggregated metrics.
    pub metrics: JobMetrics,
}

/// Per-bucket cache of warm XLA dispatchers, keyed by `(m, batch)`.
type DispatcherCache = std::sync::Mutex<
    std::collections::HashMap<(usize, usize), std::sync::Arc<dispatch::XlaDispatcher>>,
>;

/// The L3 coordinator. Cheap to construct; one instance serves many jobs.
///
/// XLA dispatchers (PJRT sessions + compiled executables) are cached per
/// `(m, batch)` bucket and reused across jobs — compilation happens once
/// per bucket per coordinator, not per request (EXPERIMENTS.md §Perf
/// iteration 4: ~0.7 s saved on every small XLA job after the first).
pub struct Coordinator {
    cfg: CoordinatorConfig,
    manifest: Option<Manifest>,
    dispatchers: DispatcherCache,
}

impl Coordinator {
    /// Build a coordinator. The artifact manifest is loaded lazily-
    /// tolerantly: absence is only an error if a job later *requires*
    /// the XLA engine.
    pub fn new(cfg: CoordinatorConfig) -> Result<Self> {
        let manifest = resolve_artifact_dir(cfg.artifact_dir.as_deref())
            .map(|dir| Manifest::load(&dir))
            .transpose()?;
        if matches!(cfg.engine, EngineKind::Xla) && manifest.is_none() {
            return Err(Error::Artifact(
                "EngineKind::Xla requires artifacts — run `make artifacts`".into(),
            ));
        }
        Ok(Self {
            cfg,
            manifest,
            dispatchers: std::sync::Mutex::new(std::collections::HashMap::new()),
        })
    }

    /// Effective worker count.
    pub fn workers(&self) -> usize {
        if self.cfg.workers > 0 {
            self.cfg.workers
        } else {
            std::thread::available_parallelism().map_or(4, |p| p.get())
        }
    }

    /// Configuration in use.
    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    /// Loaded manifest (if any).
    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_ref()
    }

    /// Parallel Radić determinant (Definition 3) of an `m×n` matrix.
    pub fn radic_det(&self, a: &MatF64) -> Result<RadicOutput> {
        let (m, n) = (a.rows(), a.cols());
        if m > n {
            // Definition 3: det(A) = 0 when m > n — no enumeration.
            return Ok(RadicOutput {
                det: 0.0,
                terms: 0,
                engine: "none",
                metrics: JobMetrics::default(),
            });
        }
        let total = combination_count(n as u64, m as u64)?;
        if total > self.cfg.term_cap {
            return Err(Error::JobTooLarge {
                n: n as u64,
                m: m as u64,
                total,
                cap: self.cfg.term_cap,
            });
        }

        // The prefix engine has its own block-oriented worker loop.
        if matches!(self.cfg.engine, EngineKind::Prefix) {
            return self.radic_det_prefix(a, total);
        }

        // Engine selection.
        let use_xla = match self.cfg.engine {
            EngineKind::Cpu | EngineKind::Prefix => false,
            EngineKind::Xla => true,
            EngineKind::Auto => self
                .manifest
                .as_ref()
                .map(|man| man.find(m, Dtype::F64, self.cfg.batch).is_ok())
                .unwrap_or(false),
        };

        let workers = self.workers();
        let started = Instant::now();
        let (label, batch, dispatcher) = if use_xla {
            let man = self.manifest.as_ref().ok_or_else(|| {
                Error::Artifact("XLA engine requested but no manifest loaded".into())
            })?;
            let spec = man.find(m, Dtype::F64, self.cfg.batch)?;
            // Reuse (or build) the cached dispatcher for this bucket.
            let d = {
                let mut cache = self.dispatchers.lock().expect("dispatcher cache poisoned");
                match cache.entry((spec.m, spec.batch)) {
                    std::collections::hash_map::Entry::Occupied(e) => std::sync::Arc::clone(e.get()),
                    std::collections::hash_map::Entry::Vacant(e) => {
                        let d = std::sync::Arc::new(dispatch::XlaDispatcher::start(
                            spec,
                            self.cfg.xla_executors.max(1),
                        )?);
                        e.insert(std::sync::Arc::clone(&d));
                        d
                    }
                }
            };
            ("xla-pjrt", spec.batch, Some(d))
        } else {
            ("cpu-lu", self.cfg.batch.max(1), None)
        };

        // Per-worker engines (built up front; moved into threads).
        let engines: Vec<Box<dyn DetEngine + Send>> = (0..workers)
            .map(|_| -> Box<dyn DetEngine + Send> {
                match &dispatcher {
                    Some(d) => Box::new(d.handle()),
                    None => Box::new(CpuEngine::new(m, batch)),
                }
            })
            .collect();

        let table = PascalTable::new(n as u64, m as u64)?;
        let job = JobSchedule::new(self.cfg.schedule, total, workers);

        let results: Vec<Result<(NeumaierSum, WorkerMetrics)>> =
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(workers);
                for (w, eng) in engines.into_iter().enumerate() {
                    let table = &table;
                    let job = &job;
                    handles.push(scope.spawn(move || worker_loop(w, eng, a, table, job)));
                }
                handles
                    .into_iter()
                    .map(|h| h.join().expect("worker panicked"))
                    .collect()
            });

        drop(dispatcher); // cached — executor threads stay warm

        // Deterministic merge in worker order.
        let mut sum = NeumaierSum::new();
        let mut jm = JobMetrics::default();
        for r in results {
            let (partial, wm) = r?;
            sum.merge(&partial);
            jm.workers.push(wm);
        }
        jm.elapsed = started.elapsed();
        Ok(RadicOutput { det: sum.value(), terms: total, engine: label, metrics: jm })
    }

    /// Prefix-engine job: block-aligned schedule, one prefix
    /// factorization per sibling block, O(m) Laplace dot per term.
    fn radic_det_prefix(&self, a: &MatF64, total: u128) -> Result<RadicOutput> {
        let (m, n) = (a.rows(), a.cols());
        let workers = self.workers();
        let started = Instant::now();
        let table = PascalTable::new(n as u64, m as u64)?;
        let job = JobSchedule::new_block_aligned(self.cfg.schedule, total, workers, &table)?;
        let results: Vec<Result<(NeumaierSum, WorkerMetrics)>> =
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(workers);
                for w in 0..workers {
                    let table = &table;
                    let job = &job;
                    handles.push(scope.spawn(move || prefix_worker_loop(w, a, table, job)));
                }
                handles
                    .into_iter()
                    .map(|h| h.join().expect("worker panicked"))
                    .collect()
            });
        let mut sum = NeumaierSum::new();
        let mut jm = JobMetrics::default();
        for r in results {
            let (partial, wm) = r?;
            sum.merge(&partial);
            jm.workers.push(wm);
        }
        jm.elapsed = started.elapsed();
        Ok(RadicOutput { det: sum.value(), terms: total, engine: "prefix", metrics: jm })
    }

    /// Parallel exact Radić determinant in any integer scalar of the
    /// tower — checked `i128` ([`Self::radic_det_exact`]) or unbounded
    /// [`BigInt`] ([`Self::radic_det_big`]) — over the same worker
    /// loops, schedules and chunk leases as the float path.
    ///
    /// With [`EngineKind::Prefix`] the inner engine switches to exact
    /// *prefix cofactors* shared across each sibling block — the
    /// integer twin of the float prefix path (no rank fallback needed:
    /// integer arithmetic is exact, singular prefixes simply yield
    /// zero cofactors).
    pub fn radic_det_scalar<S>(&self, a: &MatI64) -> Result<(S, JobMetrics)>
    where
        S: ScalarExec + Scalar<Elem = i64>,
    {
        let (m, n) = (a.rows(), a.cols());
        if m > n {
            return Ok((S::zero(), JobMetrics::default()));
        }
        let total = combination_count(n as u64, m as u64)?;
        if total > self.cfg.term_cap {
            return Err(Error::JobTooLarge {
                n: n as u64,
                m: m as u64,
                total,
                cap: self.cfg.term_cap,
            });
        }
        let workers = self.workers();
        let started = Instant::now();
        let table = PascalTable::new(n as u64, m as u64)?;
        let use_prefix = matches!(self.cfg.engine, EngineKind::Prefix);
        let job = if use_prefix {
            JobSchedule::new_block_aligned(self.cfg.schedule, total, workers, &table)?
        } else {
            JobSchedule::new(self.cfg.schedule, total, workers)
        };
        let partials: Vec<Result<(S, WorkerMetrics)>> = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(workers);
            for w in 0..workers {
                let table = &table;
                let job = &job;
                handles.push(
                    scope.spawn(move || scalar_worker_loop::<S>(w, a, table, job, use_prefix)),
                );
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("worker panicked"))
                .collect()
        });
        let mut acc = S::accum_new();
        let mut jm = JobMetrics::default();
        for p in partials {
            let (partial, wm) = p?;
            S::accum_add(&mut acc, &partial, "radic sum")?;
            jm.workers.push(wm);
        }
        jm.elapsed = started.elapsed();
        Ok((S::accum_value(&acc), jm))
    }

    /// Parallel exact Radić determinant over checked `i128` — overflow
    /// surfaces as [`Error::ScalarOverflow`], never a wrapped value.
    pub fn radic_det_exact(&self, a: &MatI64) -> Result<i128> {
        Ok(self.radic_det_scalar::<i128>(a)?.0)
    }

    /// [`Self::radic_det_exact`] plus per-worker metrics — the exact
    /// path reports terms/chunks/blocks like the float path.
    pub fn radic_det_exact_with_metrics(&self, a: &MatI64) -> Result<(i128, JobMetrics)> {
        self.radic_det_scalar::<i128>(a)
    }

    /// Parallel exact Radić determinant over unbounded big integers —
    /// the overflow-proof path for workloads past `i128`.
    pub fn radic_det_big(&self, a: &MatI64) -> Result<BigInt> {
        Ok(self.radic_det_scalar::<BigInt>(a)?.0)
    }

    /// [`Self::radic_det_big`] plus per-worker metrics.
    pub fn radic_det_big_with_metrics(&self, a: &MatI64) -> Result<(BigInt, JobMetrics)> {
        self.radic_det_scalar::<BigInt>(a)
    }
}

/// One worker: claim chunks, execute each as a lease
/// ([`LeaseRunner::run_chunk`] — the same unit the durable jobs
/// subsystem journals), merge chunk partials in claim order.
fn worker_loop(
    w: usize,
    eng: Box<dyn DetEngine + Send>,
    a: &MatF64,
    table: &PascalTable,
    job: &JobSchedule,
) -> Result<(NeumaierSum, WorkerMetrics)> {
    let mut runner = LeaseRunner::<f64>::lanes(eng);
    let mut acc = NeumaierSum::new();
    let mut wm = WorkerMetrics::default();
    let mut src = job.source(w);
    while let Some(chunk) = src.next_chunk() {
        let (partial, cm) = runner.run_chunk(a, table, chunk)?;
        acc.add(partial);
        wm.merge(&cm);
    }
    Ok((acc, wm))
}

/// Prefix-engine worker: block-aligned chunk leases, one factorization
/// + O(m) dots per sibling block.
///
/// The gather/factorize/dot phases are fused per block, so all time is
/// booked as `engine_time` (`gather_time` stays 0 on this path).
fn prefix_worker_loop(
    w: usize,
    a: &MatF64,
    table: &PascalTable,
    job: &JobSchedule,
) -> Result<(NeumaierSum, WorkerMetrics)> {
    let mut runner = LeaseRunner::<f64>::prefix(a.rows());
    let mut acc = NeumaierSum::new();
    let mut wm = WorkerMetrics::default();
    let mut src = job.source(w);
    while let Some(chunk) = src.next_chunk() {
        let (partial, cm) = runner.run_chunk(a, table, chunk)?;
        acc.add(partial);
        wm.merge(&cm);
    }
    Ok((acc, wm))
}

/// Exact-path worker for any integer scalar of the tower: chunk leases
/// on the generic [`LeaseRunner`] (per-term Bareiss, or exact prefix
/// cofactors shared per sibling block when `use_prefix`).
fn scalar_worker_loop<S>(
    w: usize,
    a: &MatI64,
    table: &PascalTable,
    job: &JobSchedule,
    use_prefix: bool,
) -> Result<(S, WorkerMetrics)>
where
    S: ScalarExec + Scalar<Elem = i64>,
{
    let mut runner = LeaseRunner::<S>::new(a.rows(), use_prefix, 0);
    let mut acc = S::accum_new();
    let mut wm = WorkerMetrics::default();
    let mut src = job.source(w);
    while let Some(chunk) = src.next_chunk() {
        let (partial, cm) = runner.run_chunk(a, table, chunk)?;
        S::accum_add(&mut acc, &partial, "radic sum")?;
        wm.merge(&cm);
    }
    Ok((S::accum_value(&acc), wm))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{radic_det_exact, radic_det_seq};
    use crate::matrix::gen;
    use crate::testkit::TestRng;

    fn cpu_coord(workers: usize, schedule: Schedule) -> Coordinator {
        Coordinator::new(CoordinatorConfig {
            workers,
            engine: EngineKind::Cpu,
            schedule,
            batch: 32,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn parallel_matches_sequential_static() {
        let a = gen::uniform(&mut TestRng::from_seed(1), 4, 12, -1.0, 1.0);
        let seq = radic_det_seq(&a).unwrap();
        for workers in [1, 2, 5] {
            let out = cpu_coord(workers, Schedule::Static).radic_det(&a).unwrap();
            assert_eq!(out.terms, 495);
            assert!(
                (out.det - seq).abs() < 1e-9 * seq.abs().max(1.0),
                "workers={workers}: {} vs {seq}",
                out.det
            );
            assert_eq!(out.metrics.total().terms, 495);
        }
    }

    #[test]
    fn parallel_matches_sequential_stealing() {
        let a = gen::uniform(&mut TestRng::from_seed(2), 3, 14, -1.0, 1.0);
        let seq = radic_det_seq(&a).unwrap();
        let out = cpu_coord(4, Schedule::WorkStealing { grain: 17 })
            .radic_det(&a)
            .unwrap();
        assert!((out.det - seq).abs() < 1e-9 * seq.abs().max(1.0));
        assert_eq!(out.metrics.total().terms, 364); // C(14,3)
    }

    #[test]
    fn m_greater_than_n_short_circuits() {
        let a = gen::uniform(&mut TestRng::from_seed(3), 5, 3, -1.0, 1.0);
        let out = cpu_coord(2, Schedule::Static).radic_det(&a).unwrap();
        assert_eq!(out.det, 0.0);
        assert_eq!(out.terms, 0);
    }

    #[test]
    fn term_cap_enforced() {
        let mut cfg = CoordinatorConfig {
            engine: EngineKind::Cpu,
            term_cap: 100,
            ..Default::default()
        };
        cfg.workers = 2;
        let coord = Coordinator::new(cfg).unwrap();
        let a = gen::uniform(&mut TestRng::from_seed(4), 4, 12, -1.0, 1.0);
        assert!(matches!(
            coord.radic_det(&a),
            Err(Error::JobTooLarge { .. })
        ));
    }

    #[test]
    fn exact_parallel_matches_sequential() {
        let a = gen::integer(&mut TestRng::from_seed(5), 3, 9, -7, 7);
        let seq = radic_det_exact(&a).unwrap();
        for workers in [1, 3] {
            let got = cpu_coord(workers, Schedule::Static)
                .radic_det_exact(&a)
                .unwrap();
            assert_eq!(got, seq, "workers={workers}");
        }
    }

    fn prefix_coord(workers: usize, schedule: Schedule) -> Coordinator {
        Coordinator::new(CoordinatorConfig {
            workers,
            engine: EngineKind::Prefix,
            schedule,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn prefix_engine_matches_sequential_static_and_stealing() {
        let a = gen::uniform(&mut TestRng::from_seed(7), 4, 12, -1.0, 1.0);
        let seq = radic_det_seq(&a).unwrap();
        for workers in [1, 2, 5] {
            let out = prefix_coord(workers, Schedule::Static).radic_det(&a).unwrap();
            assert_eq!(out.engine, "prefix");
            assert_eq!(out.terms, 495);
            assert_eq!(out.metrics.total().terms, 495);
            assert!(out.metrics.total().blocks > 0, "blocks metered");
            assert!(
                (out.det - seq).abs() < 1e-9 * seq.abs().max(1.0),
                "workers={workers}: {} vs {seq}",
                out.det
            );
        }
        let ws = prefix_coord(3, Schedule::WorkStealing { grain: 11 })
            .radic_det(&a)
            .unwrap();
        assert!((ws.det - seq).abs() < 1e-9 * seq.abs().max(1.0));
        assert_eq!(ws.metrics.total().terms, 495);
    }

    #[test]
    fn prefix_exact_matches_sequential() {
        let a = gen::integer(&mut TestRng::from_seed(8), 3, 10, -7, 7);
        let seq = radic_det_exact(&a).unwrap();
        for workers in [1, 4] {
            let (got, jm) = prefix_coord(workers, Schedule::Static)
                .radic_det_exact_with_metrics(&a)
                .unwrap();
            assert_eq!(got, seq, "workers={workers}");
            assert_eq!(jm.total().terms as u128, 120); // C(10,3)
            assert!(jm.total().blocks > 0);
        }
    }

    #[test]
    fn big_scalar_matches_i128_and_survives_overflow() {
        use crate::scalar::BigInt;
        let a = gen::integer(&mut TestRng::from_seed(10), 3, 9, -7, 7);
        let narrow = radic_det_exact(&a).unwrap();
        for engine in [EngineKind::Cpu, EngineKind::Prefix] {
            let coord = Coordinator::new(CoordinatorConfig {
                workers: 3,
                engine,
                schedule: Schedule::Static,
                ..Default::default()
            })
            .unwrap();
            assert_eq!(coord.radic_det_big(&a).unwrap(), BigInt::from_i128(narrow));
        }
        // Past i128: the checked path refuses loudly, the big path
        // computes.
        let wide_in = gen::integer(
            &mut TestRng::from_seed(11),
            6,
            8,
            -900_000_000,
            900_000_000,
        );
        let coord = cpu_coord(2, Schedule::Static);
        assert!(matches!(
            coord.radic_det_exact(&wide_in),
            Err(Error::ScalarOverflow { .. })
        ));
        let (det, jm) = coord.radic_det_big_with_metrics(&wide_in).unwrap();
        assert_eq!(det.to_i128(), None, "determinant exceeds i128");
        assert_eq!(jm.total().terms as u128, 28); // C(8,6)
    }

    #[test]
    fn exact_path_reports_metrics() {
        let a = gen::integer(&mut TestRng::from_seed(9), 3, 9, -5, 5);
        let (det, jm) = cpu_coord(3, Schedule::Static)
            .radic_det_exact_with_metrics(&a)
            .unwrap();
        assert_eq!(det, radic_det_exact(&a).unwrap());
        assert_eq!(jm.total().terms as u128, 84); // C(9,3)
        assert!(jm.total().chunks >= 1);
        assert_eq!(jm.workers.len(), 3);
    }

    #[test]
    fn square_case_is_plain_det() {
        let a = gen::uniform(&mut TestRng::from_seed(6), 5, 5, -2.0, 2.0);
        let out = cpu_coord(3, Schedule::Static).radic_det(&a).unwrap();
        let plain = crate::linalg::det_lu(a.data(), 5);
        assert!((out.det - plain).abs() < 1e-10 * plain.abs().max(1.0));
        assert_eq!(out.terms, 1);
    }
}
