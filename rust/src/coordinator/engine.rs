//! Determinant engines — the pluggable inner loop of the coordinator.
//!
//! [`CpuEngine`] evaluates batches with the in-crate LU (same pivoting
//! policy as the Pallas kernel, so Cpu and Xla agree to rounding).
//! [`super::dispatch::XlaEngineHandle`] is the XLA-backed implementation;
//! both implement [`DetEngine`], which is what workers program against.

use crate::linalg::{det_lu_inplace, NeumaierSum};
use crate::runtime::BatchResult;
use crate::Result;

/// A batched signed-determinant evaluator.
///
/// `run_batch` receives *padded* buffers (`subs`: `(batch, m, m)`
/// row-major; `signs`: `(batch,)` with zeros on padding lanes) and
/// returns the signed partial sum plus per-lane dets. `subs` is mutable
/// and **consumed**: in-place engines (LU) eliminate directly in the
/// batch buffer instead of copying each lane to scratch
/// (EXPERIMENTS.md §Perf iteration 3).
pub trait DetEngine {
    /// Submatrix order the engine is specialized for.
    fn m(&self) -> usize;
    /// Batch size the engine expects.
    fn batch(&self) -> usize;
    /// Evaluate one (padded) batch, destroying `subs`.
    fn run_batch(&mut self, subs: &mut [f64], signs: &[f64]) -> Result<BatchResult>;
    /// Engine label for metrics/CLI output.
    fn label(&self) -> &'static str;
}

/// Pure-rust LU engine (no artifacts required).
pub struct CpuEngine {
    m: usize,
    batch: usize,
}

impl CpuEngine {
    /// New engine for `(m, batch)`.
    pub fn new(m: usize, batch: usize) -> Self {
        Self { m, batch }
    }
}

impl DetEngine for CpuEngine {
    fn m(&self) -> usize {
        self.m
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn run_batch(&mut self, subs: &mut [f64], signs: &[f64]) -> Result<BatchResult> {
        let (m, mm) = (self.m, self.m * self.m);
        debug_assert_eq!(subs.len(), self.batch * mm);
        debug_assert_eq!(signs.len(), self.batch);
        let mut dets = Vec::with_capacity(self.batch);
        let mut acc = NeumaierSum::new();
        for (lane, chunk) in subs.chunks_exact_mut(mm).enumerate() {
            let det = det_lu_inplace(chunk, m);
            dets.push(det);
            let s = signs[lane];
            if s != 0.0 {
                acc.add(s * det);
            }
        }
        Ok(BatchResult { partial: acc.value(), dets })
    }

    fn label(&self) -> &'static str {
        "cpu-lu"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchBuilder;
    use crate::matrix::{gen, Mat};
    use crate::testkit::TestRng;

    #[test]
    fn cpu_engine_signed_sum() {
        let a = Mat::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let mut b = BatchBuilder::new(2, 4);
        for cols in [[1u32, 2], [1, 3], [2, 3]] {
            b.push(&a, &cols);
        }
        let (subs, signs, _) = b.finalize();
        let signs = signs.to_vec();
        let mut eng = CpuEngine::new(2, 4);
        let out = eng.run_batch(subs, &signs).unwrap();
        // +D12 − D13 + D23 = −3 + 6 − 3 = 0.
        assert!(out.partial.abs() < 1e-12, "partial {}", out.partial);
        assert_eq!(out.dets.len(), 4);
        assert_eq!(out.dets[3], 1.0, "identity padding lane");
    }

    #[test]
    fn padding_lanes_do_not_contribute() {
        let a = gen::uniform(&mut TestRng::from_seed(3), 3, 5, -1.0, 1.0);
        let mut partial = BatchBuilder::new(3, 8);
        for cols in [[1u32, 2, 3], [1, 2, 4], [1, 2, 5]] {
            partial.push(&a, &cols);
        }
        let mut eng = CpuEngine::new(3, 8);
        let (s1, g1, _) = partial.finalize();
        let g1 = g1.to_vec();
        let r1 = eng.run_batch(s1, &g1).unwrap();
        let manual: f64 = r1.dets.iter().zip(&g1).map(|(d, s)| d * s).sum();
        assert!((r1.partial - manual).abs() < 1e-12);
    }
}
