//! Determinant engines — the pluggable inner loop of the coordinator.
//!
//! [`CpuEngine`] evaluates padded batches with the in-crate LU (same
//! pivoting policy as the Pallas kernel, so Cpu and Xla agree to
//! rounding); [`super::dispatch::XlaEngineHandle`] is the XLA-backed
//! implementation. Both implement [`DetEngine`], which is what batch
//! workers program against.
//!
//! [`PrefixEngine`] is the third evaluator and deliberately does *not*
//! implement [`DetEngine`]: it consumes sibling *blocks* (shared m−1
//! column prefix + last-column range, see [`crate::combin::prefix`])
//! instead of padded lanes, factorizing each prefix once
//! ([`MinorsWorkspace`]) and reducing every sibling determinant to an
//! O(m) dot product — O(m³/w + m) per term for width-w blocks versus
//! the per-term O(m³) of the LU lane engines.

use crate::combin::radic_sign;
use crate::linalg::{det_lu_inplace, KernelKind, LaneBuffer, MinorsWorkspace, NeumaierSum};
use crate::matrix::MatF64;
use crate::Result;

/// A batched signed-determinant evaluator.
///
/// `run_batch` receives *padded* buffers (`subs`: `(batch, m, m)`
/// row-major; `signs`: `(batch,)` with zeros on padding lanes) and
/// returns the signed partial sum. `subs` is mutable and **consumed**:
/// in-place engines (LU) eliminate directly in the batch buffer instead
/// of copying each lane to scratch (EXPERIMENTS.md §Perf iteration 3).
/// Per-lane determinants land in a per-engine buffer exposed by
/// [`Self::dets`] — valid until the next `run_batch` — so the hot path
/// allocates nothing per batch (EXPERIMENTS.md §Perf iteration 5).
pub trait DetEngine {
    /// Submatrix order the engine is specialized for.
    fn m(&self) -> usize;
    /// Batch size the engine expects.
    fn batch(&self) -> usize;
    /// Evaluate one (padded) batch, destroying `subs`; returns the
    /// signed partial sum `Σ signs[b]·det(subs[b])`.
    fn run_batch(&mut self, subs: &mut [f64], signs: &[f64]) -> Result<f64>;
    /// Per-lane determinants of the most recent batch (length =
    /// [`Self::batch`]; empty before the first batch).
    fn dets(&self) -> &[f64];
    /// Engine label for metrics/CLI output.
    fn label(&self) -> &'static str;
}

/// Pure-rust LU engine (no artifacts required).
pub struct CpuEngine {
    m: usize,
    batch: usize,
    /// Reused per-lane determinant buffer (see [`DetEngine::dets`]).
    dets: Vec<f64>,
}

impl CpuEngine {
    /// New engine for `(m, batch)`.
    pub fn new(m: usize, batch: usize) -> Self {
        Self { m, batch, dets: Vec::with_capacity(batch) }
    }
}

impl DetEngine for CpuEngine {
    fn m(&self) -> usize {
        self.m
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn run_batch(&mut self, subs: &mut [f64], signs: &[f64]) -> Result<f64> {
        let (m, mm) = (self.m, self.m * self.m);
        debug_assert_eq!(subs.len(), self.batch * mm);
        debug_assert_eq!(signs.len(), self.batch);
        self.dets.clear();
        let mut acc = NeumaierSum::new();
        for (lane, chunk) in subs.chunks_exact_mut(mm).enumerate() {
            let det = det_lu_inplace(chunk, m);
            self.dets.push(det);
            let s = signs[lane];
            if s != 0.0 {
                acc.add(s * det);
            }
        }
        Ok(acc.value())
    }

    fn dets(&self) -> &[f64] {
        &self.dets
    }

    fn label(&self) -> &'static str {
        "cpu-lu"
    }
}

/// Outcome of one sibling block evaluated by [`PrefixEngine`].
#[derive(Clone, Copy, Debug)]
pub struct BlockOutcome {
    /// Signed partial sum over the block's siblings.
    pub partial: f64,
    /// Sibling combinations evaluated.
    pub terms: u64,
    /// True when the prefix was rank-deficient and the block was
    /// re-evaluated with per-sibling pivoted LU.
    pub fell_back: bool,
}

/// Prefix-factored Laplace engine.
///
/// Per block: gather the shared m×(m−1) prefix once, compute its m
/// Laplace cofactors in one pivoted elimination
/// ([`MinorsWorkspace::cofactors`]), then each sibling determinant is
/// `Σᵢ cᵢ·A[i, j]` — O(m) instead of the O(m³) gather+LU of the lane
/// engines. The per-sibling dots are evaluated by a runtime-dispatched
/// SIMD kernel ([`KernelKind`], `RADDET_KERNEL` to force one) that is
/// bit-identical to the scalar loop by construction (see
/// [`crate::linalg::simd`]); sign application and the Neumaier
/// accumulation over the block stay in shared scalar code either way.
/// Rank-deficient prefixes fall back to the exact same per-sibling LU
/// the [`CpuEngine`] runs (metered, never silent).
///
/// All scratch is owned by the engine and reused across blocks — the
/// steady-state hot path performs zero allocations.
pub struct PrefixEngine {
    m: usize,
    ws: MinorsWorkspace,
    /// Dot kernel evaluating the sibling lanes (captured at
    /// construction — [`KernelKind::active`] by default).
    kernel: KernelKind,
    /// Per-lane determinants of the current block.
    lanes: LaneBuffer,
    /// Gathered m×(m−1) prefix.
    prefix_buf: Vec<f64>,
    /// Laplace cofactors of the current prefix.
    cof: Vec<f64>,
    /// Column selection scratch for the fallback gather.
    cols_buf: Vec<u32>,
    /// m×m scratch for the fallback LU.
    lu_buf: Vec<f64>,
}

impl PrefixEngine {
    /// New engine for m-row jobs, on the process-wide active kernel.
    pub fn new(m: usize) -> Self {
        Self::with_kernel(m, KernelKind::active())
    }

    /// New engine on an explicit kernel — for tests and benches that
    /// compare kernels in one process (the environment override is
    /// read once; this bypasses it). Refuses kernels the CPU lacks.
    pub fn with_kernel(m: usize, kernel: KernelKind) -> Self {
        assert!(m >= 1);
        assert!(kernel.available(), "kernel {kernel} not supported by this CPU");
        Self {
            m,
            ws: MinorsWorkspace::new(m),
            kernel,
            lanes: LaneBuffer::new(),
            prefix_buf: vec![0.0; m * (m - 1)],
            cof: vec![0.0; m],
            cols_buf: vec![0; m],
            lu_buf: vec![0.0; m * m],
        }
    }

    /// Submatrix order.
    pub fn m(&self) -> usize {
        self.m
    }

    /// The dot kernel this engine runs.
    pub fn kernel(&self) -> KernelKind {
        self.kernel
    }

    /// Engine label for metrics/CLI output.
    pub fn label(&self) -> &'static str {
        "prefix"
    }

    /// Evaluate one sibling block: columns `(prefix…, j)` for
    /// `last_lo ≤ j ≤ last_hi` against matrix `a` (`a.rows() == m`,
    /// 1-based column indices, `prefix.len() == m−1`).
    pub fn run_block(
        &mut self,
        a: &MatF64,
        prefix: &[u32],
        last_lo: u32,
        last_hi: u32,
    ) -> BlockOutcome {
        let m = self.m;
        debug_assert_eq!(a.rows(), m);
        debug_assert_eq!(prefix.len(), m - 1);
        debug_assert!(last_lo <= last_hi && (last_hi as usize) <= a.cols());
        let terms = (last_hi - last_lo + 1) as u64;

        a.gather_cols_into(prefix, &mut self.prefix_buf);
        if !self.ws.cofactors(&self.prefix_buf, &mut self.cof) {
            return BlockOutcome {
                partial: self.run_block_fallback(a, prefix, last_lo, last_hi),
                terms,
                fell_back: true,
            };
        }

        // The sibling lanes are contiguous inside each row (columns
        // last_lo..=last_hi), so the kernel reads the matrix directly;
        // only the per-lane determinants are written to scratch.
        let dets = self.lanes.lanes(terms as usize);
        self.kernel
            .dot_block(a.data(), a.cols(), (last_lo - 1) as usize, &self.cof, dets);

        // Radić sign (−1)^(r+s) with s = Σ prefix + j: alternates as j
        // sweeps the block. Sign + accumulation stay scalar and
        // kernel-independent — only the dots above are dispatched.
        let mut sign = block_sign(prefix, last_lo);
        let mut acc = NeumaierSum::new();
        for &det in dets.iter() {
            acc.add(sign * det);
            sign = -sign;
        }
        BlockOutcome { partial: acc.value(), terms, fell_back: false }
    }

    /// Rank-deficient-prefix fallback: per-sibling gather + pivoted LU,
    /// identical arithmetic to [`CpuEngine`] so a degenerate prefix can
    /// never change the answer, only the speed.
    fn run_block_fallback(
        &mut self,
        a: &MatF64,
        prefix: &[u32],
        last_lo: u32,
        last_hi: u32,
    ) -> f64 {
        let m = self.m;
        self.cols_buf[..m - 1].copy_from_slice(prefix);
        let mut acc = NeumaierSum::new();
        for j in last_lo..=last_hi {
            self.cols_buf[m - 1] = j;
            a.gather_cols_into(&self.cols_buf, &mut self.lu_buf);
            let det = det_lu_inplace(&mut self.lu_buf, m);
            acc.add(radic_sign(&self.cols_buf) * det);
        }
        acc.value()
    }
}

/// Radić sign of `(prefix…, last)` without materializing the combination.
#[inline]
fn block_sign(prefix: &[u32], last: u32) -> f64 {
    let m = prefix.len() as u64 + 1;
    let r = m * (m + 1) / 2;
    let s: u64 = prefix.iter().map(|&c| c as u64).sum::<u64>() + last as u64;
    if (r + s) % 2 == 0 {
        1.0
    } else {
        -1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchBuilder;
    use crate::matrix::{gen, Mat};
    use crate::testkit::TestRng;

    #[test]
    fn cpu_engine_signed_sum() {
        let a = Mat::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let mut b = BatchBuilder::new(2, 4);
        for cols in [[1u32, 2], [1, 3], [2, 3]] {
            b.push(&a, &cols);
        }
        let (subs, signs, _) = b.finalize();
        let signs = signs.to_vec();
        let mut eng = CpuEngine::new(2, 4);
        let partial = eng.run_batch(subs, &signs).unwrap();
        // +D12 − D13 + D23 = −3 + 6 − 3 = 0.
        assert!(partial.abs() < 1e-12, "partial {partial}");
        assert_eq!(eng.dets().len(), 4);
        assert_eq!(eng.dets()[3], 1.0, "identity padding lane");
    }

    #[test]
    fn padding_lanes_do_not_contribute() {
        let a = gen::uniform(&mut TestRng::from_seed(3), 3, 5, -1.0, 1.0);
        let mut partial = BatchBuilder::new(3, 8);
        for cols in [[1u32, 2, 3], [1, 2, 4], [1, 2, 5]] {
            partial.push(&a, &cols);
        }
        let mut eng = CpuEngine::new(3, 8);
        let (s1, g1, _) = partial.finalize();
        let g1 = g1.to_vec();
        let r1 = eng.run_batch(s1, &g1).unwrap();
        let manual: f64 = eng.dets().iter().zip(&g1).map(|(d, s)| d * s).sum();
        assert!((r1 - manual).abs() < 1e-12);
    }

    #[test]
    fn cpu_engine_det_buffer_is_reused() {
        let a = gen::uniform(&mut TestRng::from_seed(5), 2, 6, -1.0, 1.0);
        let mut eng = CpuEngine::new(2, 4);
        let mut builder = BatchBuilder::new(2, 4);
        let mut first_ptr = None;
        for round in 0..3 {
            builder.clear();
            builder.push(&a, &[1, (2 + round) as u32]);
            let (subs, signs, _) = builder.finalize();
            let signs = signs.to_vec();
            eng.run_batch(subs, &signs).unwrap();
            let ptr = eng.dets().as_ptr();
            if let Some(p) = first_ptr {
                assert_eq!(p, ptr, "dets buffer must not reallocate per batch");
            }
            first_ptr = Some(ptr);
        }
    }

    #[test]
    fn prefix_engine_matches_cpu_on_a_block() {
        let a = gen::uniform(&mut TestRng::from_seed(7), 3, 9, -2.0, 2.0);
        let mut eng = PrefixEngine::new(3);
        let out = eng.run_block(&a, &[2, 4], 5, 9);
        assert_eq!(out.terms, 5);
        assert!(!out.fell_back);
        // Reference: per-sibling LU.
        let mut want = 0.0;
        let mut scratch = vec![0.0; 9];
        for j in 5..=9u32 {
            let cols = [2, 4, j];
            a.gather_cols_into(&cols, &mut scratch);
            want += radic_sign(&cols) * det_lu_inplace(&mut scratch, 3);
        }
        assert!(
            (out.partial - want).abs() < 1e-12 * want.abs().max(1.0),
            "{} vs {want}",
            out.partial
        );
    }

    #[test]
    fn prefix_engine_m1_blocks() {
        // m=1: empty prefix, det of [a₁ⱼ] is the entry itself.
        let a = Mat::from_rows(&[vec![3.0, 5.0, 7.0, 11.0]]);
        let mut eng = PrefixEngine::new(1);
        let out = eng.run_block(&a, &[], 1, 4);
        assert_eq!(out.terms, 4);
        assert!((out.partial - (3.0 - 5.0 + 7.0 - 11.0)).abs() < 1e-12);
    }

    #[test]
    fn prefix_engine_falls_back_on_rank_deficient_prefix() {
        // Columns 1 and 2 identical ⇒ any prefix containing both is
        // singular; every sibling det is 0 and the fallback must say so.
        let mut a = gen::uniform(&mut TestRng::from_seed(9), 3, 7, -1.0, 1.0);
        for r in 0..3 {
            *a.at_mut(r, 1) = a.at(r, 0);
        }
        let mut eng = PrefixEngine::new(3);
        let out = eng.run_block(&a, &[1, 2], 3, 7);
        assert!(out.fell_back, "duplicate-column prefix must fall back");
        assert!(out.partial.abs() < 1e-12, "all siblings are singular");
        // A full-rank prefix on the same matrix still takes the fast path.
        let ok = eng.run_block(&a, &[1, 3], 4, 7);
        assert!(!ok.fell_back);
    }

    #[test]
    fn prefix_engine_kernels_bit_identical() {
        // The determinism invariant at engine level: every available
        // kernel produces the same partial bits on the same block.
        let a = gen::uniform(&mut TestRng::from_seed(11), 6, 24, -2.0, 2.0);
        let mut want = None;
        for k in KernelKind::available_kernels() {
            let mut eng = PrefixEngine::with_kernel(6, k);
            assert_eq!(eng.kernel(), k);
            // Width 18 exercises the 8/4-lane bodies plus the tail.
            let out = eng.run_block(&a, &[1, 2, 3, 4, 6], 7, 24);
            assert!(!out.fell_back);
            let bits = out.partial.to_bits();
            match want {
                None => want = Some(bits),
                Some(w) => assert_eq!(bits, w, "kernel {k} diverged"),
            }
        }
    }

    #[test]
    fn block_sign_matches_radic_sign() {
        for (prefix, last) in [(vec![1u32, 2], 3u32), (vec![2, 5], 6), (vec![1, 4], 7)] {
            let mut cols = prefix.clone();
            cols.push(last);
            assert_eq!(block_sign(&prefix, last), radic_sign(&cols), "{cols:?}");
        }
        assert_eq!(block_sign(&[], 2), radic_sign(&[2]));
    }
}
