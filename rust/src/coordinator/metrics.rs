//! Lightweight metrics: per-worker counters aggregated into a job
//! summary (printed by the CLI and consumed by the benches).

use std::time::Duration;

/// Counters collected by one worker over one job.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerMetrics {
    /// Combinations (Radić terms) processed.
    pub terms: u64,
    /// Batches submitted to the engine.
    pub batches: u64,
    /// Chunks claimed from the scheduler.
    pub chunks: u64,
    /// Sibling blocks processed (prefix engine; 0 on lane engines).
    pub blocks: u64,
    /// Blocks whose rank-deficient prefix forced the per-sibling LU
    /// fallback (prefix engine only).
    pub fallback_blocks: u64,
    /// Time enumerating + gathering (the paper's parallel part).
    pub gather_time: Duration,
    /// Time inside the engine (ref \[7\]'s inner determinant).
    pub engine_time: Duration,
}

impl WorkerMetrics {
    /// Fold another worker's counters in.
    pub fn merge(&mut self, other: &WorkerMetrics) {
        self.terms += other.terms;
        self.batches += other.batches;
        self.chunks += other.chunks;
        self.blocks += other.blocks;
        self.fallback_blocks += other.fallback_blocks;
        self.gather_time += other.gather_time;
        self.engine_time += other.engine_time;
    }
}

/// Aggregated job metrics.
#[derive(Clone, Debug, Default)]
pub struct JobMetrics {
    /// Per-worker snapshots (index = worker id).
    pub workers: Vec<WorkerMetrics>,
    /// Wall-clock for the whole job.
    pub elapsed: Duration,
}

impl JobMetrics {
    /// Sum across workers.
    pub fn total(&self) -> WorkerMetrics {
        let mut t = WorkerMetrics::default();
        for w in &self.workers {
            t.merge(w);
        }
        t
    }

    /// Terms per wall-clock second.
    pub fn throughput(&self) -> f64 {
        let terms = self.total().terms as f64;
        let secs = self.elapsed.as_secs_f64();
        if secs > 0.0 {
            terms / secs
        } else {
            0.0
        }
    }

    /// Load-balance ratio: min/max worker terms (1.0 = perfectly even).
    pub fn balance(&self) -> f64 {
        let active: Vec<u64> = self
            .workers
            .iter()
            .map(|w| w.terms)
            .filter(|&t| t > 0)
            .collect();
        match (active.iter().min(), active.iter().max()) {
            (Some(&min), Some(&max)) if max > 0 => min as f64 / max as f64,
            _ => 1.0,
        }
    }

    /// Human-readable one-job report. Block counters (prefix engine)
    /// appear only when blocks were actually processed.
    pub fn render(&self) -> String {
        let t = self.total();
        let blocks = if t.blocks > 0 {
            format!(" blocks={} fallbacks={}", t.blocks, t.fallback_blocks)
        } else {
            String::new()
        };
        format!(
            "terms={} batches={} chunks={}{blocks} workers={} elapsed={:?} throughput={:.0}/s balance={:.2}",
            t.terms,
            t.batches,
            t.chunks,
            self.workers.len(),
            self.elapsed,
            self.throughput(),
            self.balance()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_and_total() {
        let a = WorkerMetrics { terms: 10, batches: 2, chunks: 1, ..Default::default() };
        let b = WorkerMetrics { terms: 30, batches: 4, chunks: 1, ..Default::default() };
        let jm = JobMetrics { workers: vec![a, b], elapsed: Duration::from_secs(2) };
        let t = jm.total();
        assert_eq!(t.terms, 40);
        assert_eq!(t.batches, 6);
        assert_eq!(jm.throughput(), 20.0);
        assert!((jm.balance() - 10.0 / 30.0).abs() < 1e-12);
    }

    #[test]
    fn balance_ignores_idle_workers() {
        let a = WorkerMetrics { terms: 5, ..Default::default() };
        let idle = WorkerMetrics::default();
        let jm = JobMetrics { workers: vec![a, idle], elapsed: Duration::ZERO };
        assert_eq!(jm.balance(), 1.0);
    }

    #[test]
    fn block_counters_merge_and_render() {
        let a = WorkerMetrics { terms: 20, blocks: 4, fallback_blocks: 1, ..Default::default() };
        let b = WorkerMetrics { terms: 10, blocks: 2, ..Default::default() };
        let jm = JobMetrics { workers: vec![a, b], elapsed: Duration::from_millis(5) };
        let t = jm.total();
        assert_eq!((t.blocks, t.fallback_blocks), (6, 1));
        let s = jm.render();
        assert!(s.contains("blocks=6") && s.contains("fallbacks=1"), "{s}");
        // Lane engines (blocks=0) keep the old compact format.
        let lane = JobMetrics {
            workers: vec![WorkerMetrics { terms: 3, ..Default::default() }],
            elapsed: Duration::ZERO,
        };
        assert!(!lane.render().contains("blocks="));
    }

    #[test]
    fn render_mentions_terms() {
        let jm = JobMetrics {
            workers: vec![WorkerMetrics { terms: 7, ..Default::default() }],
            elapsed: Duration::from_millis(10),
        };
        assert!(jm.render().contains("terms=7"));
    }
}
