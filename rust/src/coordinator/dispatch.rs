//! XLA dispatch — executor threads that own PJRT sessions.
//!
//! `PjRtClient` is not `Send`, so XLA execution happens on dedicated
//! executor threads, each of which creates its own CPU client and
//! compiles the artifact once. Workers interact through
//! [`XlaEngineHandle`], a [`DetEngine`] that ships padded batch buffers
//! over an mpsc channel and blocks on the reply — the same
//! router/batcher shape a serving coordinator uses.

use super::engine::DetEngine;
use crate::runtime::{ArtifactSpec, BatchResult, XlaSession};
use crate::{Error, Result};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// One in-flight batch: buffers plus the reply slot.
struct Job {
    subs: Vec<f64>,
    signs: Vec<f64>,
    reply: mpsc::SyncSender<Result<BatchResult>>,
}

/// Pool of XLA executor threads sharing one job queue.
///
/// Owned behind an `Arc` in the coordinator's per-bucket cache; the
/// executors stay warm across jobs and wind down when the dispatcher is
/// dropped (the queue sender closes, each executor's `recv` errors out,
/// and `Drop` joins the threads).
pub struct XlaDispatcher {
    tx: Option<mpsc::Sender<Job>>,
    m: usize,
    batch: usize,
    threads: Vec<JoinHandle<()>>,
}

impl XlaDispatcher {
    /// Spawn `executors` threads, each compiling `spec` on its own
    /// client. Fails fast if the first executor cannot compile
    /// (artifact missing/corrupt) rather than erroring per batch.
    pub fn start(spec: &ArtifactSpec, executors: usize) -> Result<Self> {
        assert!(executors >= 1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut threads = Vec::with_capacity(executors);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for _ in 0..executors {
            let rx = Arc::clone(&rx);
            let spec = spec.clone();
            let ready = ready_tx.clone();
            threads.push(std::thread::spawn(move || {
                let exe = match XlaSession::cpu().and_then(|s| s.load(&spec)) {
                    Ok(exe) => {
                        let _ = ready.send(Ok(()));
                        exe
                    }
                    Err(e) => {
                        let _ = ready.send(Err(e));
                        return;
                    }
                };
                loop {
                    // Hold the lock only while dequeueing.
                    let job = { rx.lock().expect("queue poisoned").recv() };
                    let Ok(job) = job else { break }; // dispatcher dropped
                    let result = exe.run(&job.subs, &job.signs);
                    let _ = job.reply.send(result);
                }
            }));
        }
        drop(ready_tx);
        // All executors must come up.
        for _ in 0..executors {
            ready_rx
                .recv()
                .map_err(|_| Error::Xla("executor thread died during startup".into()))??;
        }
        Ok(Self { tx: Some(tx), m: spec.m, batch: spec.batch, threads })
    }

    /// A worker-side engine handle feeding this dispatcher.
    pub fn handle(&self) -> XlaEngineHandle {
        XlaEngineHandle {
            tx: self.tx.as_ref().expect("live dispatcher").clone(),
            m: self.m,
            batch: self.batch,
            dets: Vec::new(),
        }
    }
}

impl Drop for XlaDispatcher {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Worker-side [`DetEngine`] that proxies to the dispatcher.
pub struct XlaEngineHandle {
    tx: mpsc::Sender<Job>,
    m: usize,
    batch: usize,
    /// Most recent per-lane dets (moved from the executor's reply).
    dets: Vec<f64>,
}

impl DetEngine for XlaEngineHandle {
    fn m(&self) -> usize {
        self.m
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn run_batch(&mut self, subs: &mut [f64], signs: &[f64]) -> Result<f64> {
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.tx
            .send(Job {
                subs: subs.to_vec(),
                signs: signs.to_vec(),
                reply: reply_tx,
            })
            .map_err(|_| Error::Xla("dispatcher is gone".into()))?;
        let out: BatchResult = reply_rx
            .recv()
            .map_err(|_| Error::Xla("executor dropped the batch".into()))??;
        self.dets = out.dets; // move, not copy — the executor's vec is ours now
        Ok(out.partial)
    }

    fn dets(&self) -> &[f64] {
        &self.dets
    }

    fn label(&self) -> &'static str {
        "xla-pjrt"
    }
}

// Exercised by rust/tests/runtime_xla.rs and coordinator_e2e.rs (needs
// compiled artifacts).
