//! Batch assembly: gathers column-submatrices + Radić signs into the
//! fixed-size buffers the AOT artifact expects.
//!
//! Padding contract (shared with `python/compile/model.py`): unfilled
//! lanes hold the **identity matrix with sign 0**, so they contribute
//! exactly 0 to the partial sum and a harmless 1.0 to the dets vector.
//!
//! Perf note (EXPERIMENTS.md §Perf iteration 2): padding is applied
//! *lazily* by [`BatchBuilder::finalize`] — only the tail lanes of the
//! final short batch are written. The original eager `clear()` repadded
//! the whole 256-lane buffer (≈ 150 KiB for m=8) on every batch, which
//! showed up as ~8% of job time.

use crate::combin::radic_sign;
use crate::matrix::MatF64;

/// Reusable fixed-size batch buffer.
#[derive(Clone, Debug)]
pub struct BatchBuilder {
    m: usize,
    batch: usize,
    subs: Vec<f64>,
    signs: Vec<f64>,
    live: usize,
}

impl BatchBuilder {
    /// New builder for `(m, batch)`, fully padded.
    pub fn new(m: usize, batch: usize) -> Self {
        assert!(m >= 1 && batch >= 1);
        let mut b = Self {
            m,
            batch,
            subs: vec![0.0; batch * m * m],
            signs: vec![0.0; batch],
            live: 0,
        };
        b.pad_tail(0);
        b
    }

    /// Write identity/0-sign padding into lanes `from..batch`.
    fn pad_tail(&mut self, from: usize) {
        let (m, mm) = (self.m, self.m * self.m);
        for lane in from..self.batch {
            let buf = &mut self.subs[lane * mm..(lane + 1) * mm];
            buf.fill(0.0);
            for d in 0..m {
                buf[d * m + d] = 1.0;
            }
            self.signs[lane] = 0.0;
        }
    }

    /// Reset to empty. O(1) — stale lane contents are overwritten by
    /// subsequent `push`es and masked by `finalize`.
    #[inline]
    pub fn clear(&mut self) {
        self.live = 0;
    }

    /// Gather `a[:, cols]` into the next lane. Panics if full
    /// (callers check [`Self::is_full`]).
    #[inline]
    pub fn push(&mut self, a: &MatF64, cols: &[u32]) {
        assert!(self.live < self.batch, "batch overflow");
        debug_assert_eq!(cols.len(), self.m);
        let mm = self.m * self.m;
        let lane = &mut self.subs[self.live * mm..(self.live + 1) * mm];
        a.gather_cols_into(cols, lane);
        self.signs[self.live] = radic_sign(cols);
        self.live += 1;
    }

    /// Pad the tail (if any) and hand out the engine buffers.
    ///
    /// `subs` is mutable so in-place engines (LU) can eliminate without
    /// a scratch copy; the contents are consumed — call [`Self::clear`]
    /// before reuse.
    pub fn finalize(&mut self) -> (&mut [f64], &[f64], usize) {
        if self.live < self.batch {
            self.pad_tail(self.live);
        }
        (&mut self.subs, &self.signs, self.live)
    }

    /// Lanes currently filled.
    pub fn live(&self) -> usize {
        self.live
    }

    /// True when no lane is free.
    pub fn is_full(&self) -> bool {
        self.live == self.batch
    }

    /// True when no lane is filled.
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Batch capacity.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Submatrix order.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Read-only view of the raw buffers (tests/diagnostics; call
    /// [`Self::finalize`] first if padding must be in place).
    pub fn buffers(&self) -> (&[f64], &[f64], usize) {
        (&self.subs, &self.signs, self.live)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::det_lu;
    use crate::matrix::Mat;

    fn sample() -> MatF64 {
        Mat::from_rows(&[vec![1.0, 2.0, 3.0, 4.0], vec![5.0, 6.0, 7.0, 8.0]])
    }

    #[test]
    fn fresh_builder_is_identity_padded() {
        let b = BatchBuilder::new(3, 4);
        let (subs, signs, live) = b.buffers();
        assert_eq!(live, 0);
        assert!(signs.iter().all(|&s| s == 0.0));
        for lane in 0..4 {
            let lane_buf = &subs[lane * 9..(lane + 1) * 9];
            assert_eq!(det_lu(lane_buf, 3), 1.0, "identity lane");
        }
    }

    #[test]
    fn push_gathers_and_signs() {
        let a = sample();
        let mut b = BatchBuilder::new(2, 3);
        b.push(&a, &[1, 2]); // s=3, r=3 ⇒ +1
        b.push(&a, &[1, 3]); // s=4 ⇒ −1
        let (subs, signs, live) = b.buffers();
        assert_eq!(live, 2);
        assert_eq!(&subs[0..4], &[1.0, 2.0, 5.0, 6.0]);
        assert_eq!(&subs[4..8], &[1.0, 3.0, 5.0, 7.0]);
        assert_eq!(&signs[..2], &[1.0, -1.0]);
    }

    #[test]
    fn finalize_pads_only_the_tail() {
        let a = sample();
        let mut b = BatchBuilder::new(2, 4);
        b.push(&a, &[2, 4]);
        let (subs, signs, live) = b.finalize();
        assert_eq!(live, 1);
        assert_eq!(&subs[0..4], &[2.0, 4.0, 6.0, 8.0], "live lane untouched");
        for lane in 1..4 {
            assert_eq!(&subs[lane * 4..lane * 4 + 4], &[1.0, 0.0, 0.0, 1.0]);
            assert_eq!(signs[lane], 0.0);
        }
    }

    #[test]
    fn clear_then_refill_masks_stale_lanes() {
        let a = sample();
        let mut b = BatchBuilder::new(2, 3);
        b.push(&a, &[1, 2]);
        b.push(&a, &[1, 3]);
        b.push(&a, &[1, 4]);
        // Engines may scribble on the buffer (in-place LU).
        b.finalize().0.fill(7.7);
        b.clear();
        assert!(b.is_empty());
        b.push(&a, &[3, 4]);
        let (subs, signs, live) = b.finalize();
        assert_eq!(live, 1);
        assert_eq!(&subs[0..4], &[3.0, 4.0, 7.0, 8.0]);
        // Stale lanes 1..3 are re-padded, signs zeroed.
        for lane in 1..3 {
            assert_eq!(&subs[lane * 4..lane * 4 + 4], &[1.0, 0.0, 0.0, 1.0]);
            assert_eq!(signs[lane], 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "batch overflow")]
    fn overflow_panics() {
        let a = sample();
        let mut b = BatchBuilder::new(2, 1);
        b.push(&a, &[1, 2]);
        b.push(&a, &[1, 3]);
    }
}
