//! Deterministic reactor harness: drives the *production*
//! [`Reactor`] event loop over in-memory byte pipes, one `step()` at a
//! time, on a [`SimClock`].
//!
//! Where [`super::sim`] dispatches frames synchronously into the
//! [`ServiceCore`] (bypassing any serving shell), this harness runs the
//! actual reactor: non-blocking accept, read buffering, write
//! backpressure, slowloris/idle reaping and `JOB WAIT` parking all
//! execute the same code real TCP exercises — but single-threaded
//! (`pool_workers` is forced to `0`) and on virtual time, so a storm
//! scripted from a seed replays its event trace bit-identically.
//!
//! The pipes implement the [`NbStream`] contract exactly as TCP does:
//! reads return `Ok(None)` when the peer hasn't written, `Ok(Some(0))`
//! at half-close, and writes land in a buffer the test side drains with
//! [`SimSocket::try_recv_line`].

use crate::clock::SimClock;
use crate::service::reactor::NbListener;
use crate::service::{NbStream, Reactor, ReactorConfig, ServiceCore};
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

/// One direction of a duplex pipe.
#[derive(Default)]
struct PipeBuf {
    buf: VecDeque<u8>,
    closed: bool,
}

/// A duplex in-memory connection: client writes into `to_server`,
/// reactor replies into `to_client`.
#[derive(Default)]
struct PipePair {
    to_server: Mutex<PipeBuf>,
    to_client: Mutex<PipeBuf>,
}

/// Test-side endpoint of a simulated connection.
pub struct SimSocket {
    pair: Arc<PipePair>,
}

impl SimSocket {
    /// Queue raw bytes for the reactor to read on a future step. No
    /// newline is appended — partial frames (slowloris) are a feature.
    pub fn send_raw(&self, bytes: &[u8]) {
        let mut p = self.pair.to_server.lock().expect("pipe poisoned");
        if !p.closed {
            p.buf.extend(bytes.iter().copied());
        }
    }

    /// Queue one protocol frame (newline appended).
    pub fn send_line(&self, frame: &str) {
        self.send_raw(frame.as_bytes());
        self.send_raw(b"\n");
    }

    /// Pop one complete reply line, if the reactor has flushed one.
    pub fn try_recv_line(&self) -> Option<String> {
        let mut p = self.pair.to_client.lock().expect("pipe poisoned");
        let pos = p.buf.iter().position(|&b| b == b'\n')?;
        let raw: Vec<u8> = p.buf.drain(..=pos).collect();
        Some(String::from_utf8_lossy(&raw[..pos]).into_owned())
    }

    /// Half-close the client→server direction (the reactor sees EOF).
    pub fn close(&self) {
        self.pair.to_server.lock().expect("pipe poisoned").closed = true;
    }

    /// Has the reactor dropped its side of the connection?
    pub fn server_closed(&self) -> bool {
        self.pair.to_client.lock().expect("pipe poisoned").closed
    }

    /// Bytes of reply data not yet drained by the test.
    pub fn pending_bytes(&self) -> usize {
        self.pair.to_client.lock().expect("pipe poisoned").buf.len()
    }
}

/// Reactor-side endpoint: implements the non-blocking stream contract
/// over the shared pipes. Dropping it (the reactor closing the
/// connection) marks the reply pipe closed so the test can observe it.
struct SimNbStream {
    pair: Arc<PipePair>,
    /// Per-step write budget used to exercise partial writes: `None`
    /// writes everything offered, `Some(n)` takes at most `n` bytes per
    /// `write_nb` call.
    write_budget: Option<usize>,
}

impl NbStream for SimNbStream {
    fn read_nb(&mut self, buf: &mut [u8]) -> std::io::Result<Option<usize>> {
        let mut p = self.pair.to_server.lock().expect("pipe poisoned");
        if p.buf.is_empty() {
            return if p.closed { Ok(Some(0)) } else { Ok(None) };
        }
        let n = buf.len().min(p.buf.len());
        for (i, b) in p.buf.drain(..n).enumerate() {
            buf[i] = b;
        }
        Ok(Some(n))
    }

    fn write_nb(&mut self, buf: &[u8]) -> std::io::Result<Option<usize>> {
        let mut p = self.pair.to_client.lock().expect("pipe poisoned");
        if p.closed {
            return Err(std::io::Error::new(
                std::io::ErrorKind::BrokenPipe,
                "sim peer closed",
            ));
        }
        let n = match self.write_budget {
            Some(cap) => buf.len().min(cap),
            None => buf.len(),
        };
        if n == 0 && !buf.is_empty() {
            return Ok(None);
        }
        p.buf.extend(buf[..n].iter().copied());
        Ok(Some(n))
    }
}

impl Drop for SimNbStream {
    fn drop(&mut self) {
        self.pair.to_client.lock().expect("pipe poisoned").closed = true;
    }
}

/// Accept source fed by [`ReactorSim::connect`].
struct QueueListener {
    queue: Arc<Mutex<VecDeque<Box<dyn NbStream>>>>,
}

impl NbListener for QueueListener {
    fn accept_nb(&mut self) -> std::io::Result<Option<Box<dyn NbStream>>> {
        Ok(self.queue.lock().expect("accept queue poisoned").pop_front())
    }
}

/// The harness: a production [`Reactor`] in deterministic inline mode
/// plus an injection queue of simulated connections.
pub struct ReactorSim {
    reactor: Reactor,
    queue: Arc<Mutex<VecDeque<Box<dyn NbStream>>>>,
}

impl ReactorSim {
    /// Build a reactor over `core` on the virtual `clock`.
    /// `cfg.pool_workers` is forced to `0` (inline compute) — the only
    /// deterministic mode — and event tracing is enabled.
    pub fn new(core: Arc<ServiceCore>, mut cfg: ReactorConfig, clock: Arc<SimClock>) -> Self {
        cfg.pool_workers = 0;
        let queue: Arc<Mutex<VecDeque<Box<dyn NbStream>>>> =
            Arc::new(Mutex::new(VecDeque::new()));
        let listener = QueueListener { queue: Arc::clone(&queue) };
        let mut reactor = Reactor::new(core, Box::new(listener), cfg, clock);
        reactor.enable_trace();
        Self { reactor, queue }
    }

    /// Dial a new connection; the reactor accepts it on its next step.
    pub fn connect(&self) -> SimSocket {
        self.connect_throttled(None)
    }

    /// Like [`ReactorSim::connect`] but the reactor can write at most
    /// `budget` bytes per write call — a slow reader, for backpressure
    /// tests.
    pub fn connect_throttled(&self, budget: Option<usize>) -> SimSocket {
        let pair = Arc::new(PipePair::default());
        self.queue
            .lock()
            .expect("accept queue poisoned")
            .push_back(Box::new(SimNbStream {
                pair: Arc::clone(&pair),
                write_budget: budget,
            }));
        SimSocket { pair }
    }

    /// One reactor pass; returns its work count.
    pub fn step(&mut self) -> u64 {
        self.reactor.step()
    }

    /// Step until a pass does no work (or `max` passes). Returns total
    /// work done.
    pub fn settle(&mut self, max: u64) -> u64 {
        let mut total = 0;
        for _ in 0..max {
            let w = self.reactor.step();
            if w == 0 {
                break;
            }
            total += w;
        }
        total
    }

    /// Live connections in the reactor's table.
    pub fn conns(&self) -> usize {
        self.reactor.conn_count()
    }

    /// Drain the reactor's deterministic event trace.
    pub fn take_trace(&mut self) -> Vec<String> {
        self.reactor.take_trace()
    }
}
