//! Minimal property-testing kit (proptest is unavailable offline).
//!
//! Deterministic, seed-addressable generators over a [`TestRng`] built on
//! SplitMix64. Property helpers run a closure over many generated cases
//! and, on failure, report the seed + case index so the exact case can be
//! replayed with `TestRng::from_seed`.

mod rng;
pub mod reactor_sim;
pub mod sim;

pub use reactor_sim::{ReactorSim, SimSocket};
pub use rng::TestRng;

/// Run `prop` over `cases` generated inputs; panic with a replayable
/// seed on the first failure.
///
/// ```no_run
/// // (no_run: doctest binaries lack the libstdc++ rpath of this image)
/// use raddet::testkit::{for_all, TestRng};
/// for_all("addition commutes", 100, |rng| {
///     let (a, b) = (rng.u64_below(1000), rng.u64_below(1000));
///     assert_eq!(a + b, b + a);
/// });
/// ```
pub fn for_all<F: FnMut(&mut TestRng)>(name: &str, cases: u64, mut prop: F) {
    for case in 0..cases {
        let seed = 0x5EED_0000u64.wrapping_add(case);
        let mut rng = TestRng::from_seed(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {case} (replay seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Draw a random (n, m) pair with `1 ≤ m ≤ n ≤ max_n`.
pub fn arb_nm(rng: &mut TestRng, max_n: u64) -> (u64, u64) {
    let n = 1 + rng.u64_below(max_n);
    let m = 1 + rng.u64_below(n);
    (n, m)
}

/// Fresh per-process scratch directory under the system temp dir,
/// wiped if a previous run left it behind. `tag` must be unique across
/// the whole test suite (tests in one binary run concurrently) — by
/// convention `<module>-<test>`.
pub fn scratch_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("raddet-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_all_runs_every_case() {
        let mut count = 0;
        for_all("counter", 25, |_| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    fn arb_nm_in_range() {
        for_all("nm range", 200, |rng| {
            let (n, m) = arb_nm(rng, 12);
            assert!(m >= 1 && m <= n && n <= 12);
        });
    }

    #[test]
    #[should_panic]
    fn for_all_propagates_failure() {
        for_all("always fails", 5, |_| panic!("boom"));
    }
}
