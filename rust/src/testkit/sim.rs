//! Deterministic simulation fabric (FoundationDB-style DST) for the
//! job/fleet stack.
//!
//! Three pieces compose a fully-controlled distributed system in one
//! thread:
//!
//! * a [`SimClock`] (see [`crate::clock`]) that only moves when the
//!   scenario advances it — lease TTLs, heartbeat windows and restart
//!   gaps become explicit script steps, not wall-clock races;
//! * [`SimNet`], an in-memory [`Transport`] whose connections dispatch
//!   request frames straight into the server's [`ServiceCore`] (the
//!   byte-identical verb dispatch the TCP path uses), with injectable
//!   latency, message drops, per-peer partitions and whole-server
//!   restarts;
//! * [`SimWorld`], a seeded scheduler that steps N [`Worker`] state
//!   machines cooperatively. Every interleaving — which worker wins
//!   which grant, when a TTL expires relative to a delivery, what a
//!   restart interrupts — is a pure function of the seed, and the
//!   recorded [`SimWorld::trace`] replays identically for the same
//!   seed.
//!
//! The fabric runs the *production* code: `Worker::step`,
//! `LeaseTable::grant/complete`, journal appends and composition are
//! all the real implementations; only time and bytes-on-the-wire are
//! virtual. A scenario that fails can be handed around as a single
//! seed (see EXPERIMENTS.md §Simulation).

use crate::clock::{Clock, SimClock};
use crate::coordinator::{Coordinator, CoordinatorConfig, EngineKind, Schedule};
use crate::fleet::{FleetConfig, LeaseTable, Worker, WorkerConfig, WorkerEvent};
use crate::jobs::{
    FaultConfig, FaultFs, JobEngine, JobManager, JobPayload, JobStore, JobValue,
};
use crate::service::{Client, Conn, ConnCtx, ServiceCore, Transport};
use crate::testkit::TestRng;
use crate::{Error, Result};
use std::collections::{HashMap, HashSet, VecDeque};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Message-level fault injection knobs (all off by default).
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultPlan {
    /// Virtual time charged per delivered request/response exchange —
    /// models network latency eating into lease TTLs.
    pub latency: Duration,
    /// Per-message drop probability in parts per 10 000 (applied
    /// independently to the request and the response; a dropped message
    /// kills the connection, as TCP would surface it).
    pub drop_per_10k: u32,
}

struct NetState {
    /// The live server; `None` while "down" (between stop and start).
    core: Option<Arc<ServiceCore>>,
    /// Bumped on every server stop/restart: connections carry the
    /// generation they were dialed under and die on mismatch.
    generation: u64,
    /// Peers currently cut off from the server.
    partitioned: HashSet<String>,
    plan: FaultPlan,
    /// Extra virtual latency charged per exchange for specific peers,
    /// on top of [`FaultPlan::latency`] — the "slow worker" knob the
    /// straggler-attribution scenarios turn.
    peer_latency: HashMap<String, Duration>,
    /// Fault dice, seeded separately from the scheduler's RNG so
    /// enabling faults does not reshuffle scheduling decisions.
    rng: TestRng,
}

impl NetState {
    fn roll_drop(&mut self) -> bool {
        self.plan.drop_per_10k > 0
            && self.rng.u64_below(10_000) < self.plan.drop_per_10k as u64
    }
}

struct SimNetInner {
    clock: Arc<SimClock>,
    state: Mutex<NetState>,
    /// `(virtual ms, event)` pairs — kept structured so the trace can
    /// render both as the human `t=…ms …` lines and as JSONL
    /// (`raddet sim --trace-json`).
    trace: Mutex<Vec<(u128, String)>>,
}

impl SimNetInner {
    fn record(&self, clock_ms: u128, line: String) {
        self.trace
            .lock()
            .expect("sim trace poisoned")
            .push((clock_ms, line));
    }
}

/// The in-memory network: hands out per-peer [`Transport`]s whose
/// connections speak to the current [`ServiceCore`] synchronously.
#[derive(Clone)]
pub struct SimNet {
    inner: Arc<SimNetInner>,
}

impl SimNet {
    /// A transport dialing as `peer` (the unit of partitioning).
    pub fn peer(&self, peer: &str) -> Arc<dyn Transport> {
        Arc::new(SimPeer { inner: Arc::clone(&self.inner), peer: peer.to_string() })
    }
}

struct SimPeer {
    inner: Arc<SimNetInner>,
    peer: String,
}

impl Transport for SimPeer {
    fn connect(&self, _addr: &str) -> Result<Box<dyn Conn>> {
        let st = self.inner.state.lock().expect("sim net poisoned");
        if st.core.is_none() {
            return Err(Error::Protocol("sim: connection refused (server down)".into()));
        }
        if st.partitioned.contains(&self.peer) {
            return Err(Error::Protocol(format!(
                "sim: peer {:?} is partitioned from the server",
                self.peer
            )));
        }
        let generation = st.generation;
        drop(st);
        Ok(Box::new(SimConn {
            inner: Arc::clone(&self.inner),
            peer: self.peer.clone(),
            generation,
            ctx: ConnCtx::default(),
            inbox: VecDeque::new(),
            dead: false,
        }))
    }
}

/// One simulated connection: `send` dispatches the frame into the
/// server core immediately (after fault rolls) and queues the response
/// for `recv` — faithful to the protocol's strict request/response
/// cadence without any real I/O.
struct SimConn {
    inner: Arc<SimNetInner>,
    peer: String,
    generation: u64,
    ctx: ConnCtx,
    inbox: VecDeque<String>,
    dead: bool,
}

impl Conn for SimConn {
    fn send(&mut self, frame: &str) -> Result<()> {
        if self.dead {
            return Err(Error::Protocol("sim: connection is closed".into()));
        }
        let (core, latency) = {
            let mut st = self.inner.state.lock().expect("sim net poisoned");
            let stale = st.generation != self.generation;
            if stale || st.core.is_none() {
                drop(st);
                self.dead = true;
                return Err(Error::Protocol(
                    "sim: connection reset (server restarted)".into(),
                ));
            }
            if st.partitioned.contains(&self.peer) {
                drop(st);
                self.dead = true;
                return Err(Error::Protocol(format!(
                    "sim: peer {:?} is partitioned from the server",
                    self.peer
                )));
            }
            if st.roll_drop() {
                drop(st);
                self.dead = true;
                let ms = self.inner.clock.now().as_millis();
                self.inner
                    .record(ms, format!("net dropped request from {}", self.peer));
                return Err(Error::Protocol("sim: request lost".into()));
            }
            let extra = st
                .peer_latency
                .get(&self.peer)
                .copied()
                .unwrap_or(Duration::ZERO);
            (
                Arc::clone(st.core.as_ref().expect("checked above")),
                st.plan.latency + extra,
            )
        };
        if !latency.is_zero() {
            self.inner.clock.advance(latency);
        }
        match core.handle_line(frame.trim_end(), &mut self.ctx) {
            None => {
                // QUIT: the server closes; recv will report EOF.
                self.dead = true;
            }
            Some(response) => {
                let drop_reply = {
                    let mut st = self.inner.state.lock().expect("sim net poisoned");
                    st.roll_drop()
                };
                if drop_reply {
                    self.dead = true;
                    let ms = self.inner.clock.now().as_millis();
                    self.inner
                        .record(ms, format!("net dropped reply to {}", self.peer));
                } else {
                    self.inbox
                        .push_back(response.encode().trim_end().to_string());
                }
            }
        }
        Ok(())
    }

    fn recv(&mut self) -> Result<Option<String>> {
        if let Some(line) = self.inbox.pop_front() {
            return Ok(Some(line));
        }
        if self.dead {
            return Err(Error::Protocol("sim: connection is closed".into()));
        }
        // No queued response and not dead: the protocol is strictly
        // request/response, so this is EOF (e.g. after our own QUIT).
        Ok(None)
    }
}

/// One scheduled worker slot.
struct SimWorkerSlot {
    name: String,
    worker: Worker,
    alive: bool,
}

/// The seeded deterministic world: virtual clock + simulated network +
/// server + N cooperative workers + an event trace.
pub struct SimWorld {
    /// The virtual clock every component reads.
    pub clock: Arc<SimClock>,
    net: SimNet,
    dir: PathBuf,
    fleet_cfg: FleetConfig,
    rng: TestRng,
    workers: Vec<SimWorkerSlot>,
    /// The seeded fault-injecting filesystem every *server-side* store
    /// operation goes through when disk faults are enabled. One
    /// instance survives server restarts, so per-file durability
    /// watermarks carry across generations — a restart loses exactly
    /// the bytes an "fsync lie" pretended to persist.
    disk: Option<Arc<FaultFs>>,
    /// job id → stable alias (`job0`, `job1`, …) so traces compare
    /// equal across runs even though allocated ids differ.
    aliases: HashMap<String, String>,
    /// Virtual time charged when every live worker came up idle — the
    /// cooperative stand-in for the workers' poll sleep.
    pub idle_poll: Duration,
}

impl SimWorld {
    /// A fresh world: server up, no workers, clock at zero. `seed`
    /// fixes every scheduling and fault decision.
    pub fn new(seed: u64, dir: impl Into<PathBuf>, fleet_cfg: FleetConfig) -> SimWorld {
        SimWorld::new_with_disk(seed, dir, fleet_cfg, None)
    }

    /// Like [`SimWorld::new`], but when `disk` is `Some(cfg)` the
    /// server's journal/lock I/O is routed through a [`FaultFs`] seeded
    /// from the world seed. The fault dice start **disarmed** (fully
    /// transparent, but durability watermarks are tracked from the
    /// first byte) — call [`SimWorld::arm_disk`] once the scenario's
    /// setup traffic (submit) is done.
    pub fn new_with_disk(
        seed: u64,
        dir: impl Into<PathBuf>,
        fleet_cfg: FleetConfig,
        disk: Option<FaultConfig>,
    ) -> SimWorld {
        let clock = SimClock::new();
        let inner = Arc::new(SimNetInner {
            clock: Arc::clone(&clock),
            state: Mutex::new(NetState {
                core: None,
                generation: 0,
                partitioned: HashSet::new(),
                plan: FaultPlan::default(),
                peer_latency: HashMap::new(),
                rng: TestRng::from_seed(seed.wrapping_mul(0x9E37_79B9).wrapping_add(1)),
            }),
            trace: Mutex::new(Vec::new()),
        });
        let mut world = SimWorld {
            clock,
            net: SimNet { inner },
            dir: dir.into(),
            fleet_cfg,
            rng: TestRng::from_seed(seed),
            workers: Vec::new(),
            disk: disk.map(|cfg| FaultFs::new(seed ^ 0xD15C, cfg)),
            aliases: HashMap::new(),
            idle_poll: Duration::from_millis(50),
        };
        world.start_server();
        world
    }

    /// Arm (or quiet) the disk fault dice. No-op without
    /// [`SimWorld::new_with_disk`].
    pub fn arm_disk(&mut self, armed: bool) {
        if let Some(disk) = &self.disk {
            disk.arm(armed);
            self.record(format!("disk faults {}", if armed { "armed" } else { "disarmed" }));
        }
    }

    fn build_core(&self) -> ServiceCore {
        let mut store = JobStore::open(&self.dir)
            .expect("sim: open job store")
            .with_clock(self.clock.clone());
        if let Some(disk) = &self.disk {
            let fs: Arc<dyn crate::jobs::Fs> = Arc::clone(disk);
            store = store.with_fs(fs);
        }
        let manager = JobManager::new(store.clone(), 1).with_clock(self.clock.clone());
        let fleet = LeaseTable::with_clock(store, self.fleet_cfg, self.clock.clone());
        let coordinator = Coordinator::new(CoordinatorConfig {
            workers: 1,
            engine: EngineKind::Cpu,
            schedule: Schedule::Static,
            batch: 64,
            ..Default::default()
        })
        .expect("sim: build coordinator");
        // The core reads the same virtual clock as everything else, so
        // tenant token buckets refill on sim time, not wall time.
        ServiceCore::new(coordinator, Some(manager), Some(fleet))
            .with_clock(self.clock.clone())
    }

    /// Virtual now, for assertions.
    pub fn now_ms(&self) -> u128 {
        self.clock.now().as_millis()
    }

    fn record(&self, line: String) {
        self.net.inner.record(self.clock.now().as_millis(), line);
    }

    /// The event trace so far (scenario ops, worker step outcomes, net
    /// faults), each line stamped with virtual time. Identical for
    /// identical seeds — the replay witness.
    pub fn trace(&self) -> Vec<String> {
        self.net
            .inner
            .trace
            .lock()
            .expect("sim trace poisoned")
            .iter()
            .map(|(ms, line)| format!("t={ms}ms {line}"))
            .collect()
    }

    /// The same trace as JSON Lines — one
    /// `{"t_ms":<n>,"event":"<text>"}` object per line, for
    /// `raddet sim --trace-json` and any downstream tooling. Identical
    /// bytes for identical seeds.
    pub fn trace_jsonl(&self) -> String {
        let trace = self.net.inner.trace.lock().expect("sim trace poisoned");
        let mut out = String::new();
        for (ms, line) in trace.iter() {
            out.push_str(&format!(
                "{{\"t_ms\":{ms},\"event\":\"{}\"}}\n",
                crate::telemetry::json_escape(line)
            ));
        }
        out
    }

    /// Set message-fault knobs (latency, drop rate).
    pub fn set_faults(&mut self, plan: FaultPlan) {
        self.net.inner.state.lock().expect("sim net poisoned").plan = plan;
        self.record(format!(
            "faults latency={}ms drop={}/10k",
            plan.latency.as_millis(),
            plan.drop_per_10k
        ));
    }

    /// Charge `peer` an extra `latency` of virtual time per exchange on
    /// top of the global [`FaultPlan::latency`] — the deterministic
    /// "slow worker". Because the lease table measures grant→complete
    /// spans on the same virtual clock, this is exactly what
    /// `METRICS JOB` straggler attribution sees.
    pub fn set_peer_latency(&mut self, peer: &str, latency: Duration) {
        self.net
            .inner
            .state
            .lock()
            .expect("sim net poisoned")
            .peer_latency
            .insert(peer.to_string(), latency);
        self.record(format!("peer {peer} latency={}ms", latency.as_millis()));
    }

    /// A fresh job-store view over the world's journal directory (what
    /// an operator's `raddet job status` would see).
    pub fn store(&self) -> JobStore {
        JobStore::open(&self.dir).expect("sim: open job store")
    }

    /// Advance virtual time (the scenario's only time source).
    pub fn advance(&mut self, d: Duration) {
        self.clock.advance(d);
        self.record("advance".into());
    }

    /// Advance past the fleet lease TTL — the canonical
    /// "expire every outstanding lease" scenario step.
    pub fn expire_leases(&mut self) {
        self.clock
            .advance(self.fleet_cfg.lease_ttl + Duration::from_millis(1));
        self.record("expire-leases".into());
    }

    /// Cut `peer` off: its existing connections die on next use and new
    /// dials are refused until [`SimWorld::heal`].
    pub fn partition(&mut self, peer: &str) {
        self.net
            .inner
            .state
            .lock()
            .expect("sim net poisoned")
            .partitioned
            .insert(peer.to_string());
        self.record(format!("partition {peer}"));
    }

    /// Reconnect `peer` to the server.
    pub fn heal(&mut self, peer: &str) {
        self.net
            .inner
            .state
            .lock()
            .expect("sim net poisoned")
            .partitioned
            .remove(peer);
        self.record(format!("heal {peer}"));
    }

    /// Kill the server process: every connection dies, all in-memory
    /// lease state is lost; the journal (on disk) survives. With disk
    /// faults enabled this is a full **power loss**: tracked files are
    /// truncated back to their last honestly-fsynced byte, so anything
    /// an "fsync lie" pretended to persist is gone when the next server
    /// generation replays the journal.
    pub fn stop_server(&mut self) {
        let mut st = self.net.inner.state.lock().expect("sim net poisoned");
        st.core = None;
        st.generation += 1;
        drop(st);
        if let Some(disk) = &self.disk {
            // The core (and with it every run-lock) dropped above, so
            // the crash truncation races nothing.
            disk.crash();
            self.record("disk crash (truncate to durable watermark)".into());
        }
        self.record("server stop".into());
    }

    /// Boot a fresh server process over the same journal directory.
    pub fn start_server(&mut self) {
        let core = Arc::new(self.build_core());
        let mut st = self.net.inner.state.lock().expect("sim net poisoned");
        st.core = Some(core);
        drop(st);
        self.record("server start".into());
    }

    /// Stop + start: the crash/recovery scenario step.
    pub fn restart_server(&mut self) {
        self.stop_server();
        self.start_server();
    }

    /// A transport dialing as `peer` (for hand-driven protocol steps).
    pub fn transport(&self, peer: &str) -> Arc<dyn Transport> {
        self.net.peer(peer)
    }

    /// A fresh client connection dialing as `peer`.
    pub fn client(&self, peer: &str) -> Result<Client> {
        Ok(Client::over(self.net.peer(peer).connect("sim")?))
    }

    /// Submit a fleet job through the wire path and register a stable
    /// trace alias for it.
    pub fn submit_fleet(&mut self, payload: JobPayload, engine: JobEngine) -> Result<String> {
        let mut c = self.client("ctl")?;
        let id = c.job_submit_fleet(payload, engine)?;
        c.quit();
        let alias = format!("job{}", self.aliases.len());
        self.aliases.insert(id.clone(), alias.clone());
        self.record(format!("submit {alias}"));
        Ok(id)
    }

    fn alias(&self, id: &str) -> String {
        self.aliases.get(id).cloned().unwrap_or_else(|| "job?".into())
    }

    /// Add a worker named `name`; `tune` edits its config (pin a job,
    /// set `crash_after_grants`, …) before the first dial.
    pub fn add_worker(
        &mut self,
        name: &str,
        tune: impl FnOnce(&mut WorkerConfig),
    ) -> Result<()> {
        let mut cfg = WorkerConfig::new(name);
        tune(&mut cfg);
        let worker =
            Worker::connect(self.net.peer(name), "sim", cfg, self.clock.clone())?;
        self.workers.push(SimWorkerSlot { name: name.to_string(), worker, alive: true });
        self.record(format!("worker {name} joins"));
        Ok(())
    }

    /// Mark `name` dead without stepping it again (sudden death between
    /// steps; for death *holding a lease* use
    /// [`WorkerConfig::crash_after_grants`]).
    pub fn kill_worker(&mut self, name: &str) {
        for slot in &mut self.workers {
            if slot.name == name {
                slot.alive = false;
            }
        }
        self.record(format!("worker {name} killed"));
    }

    /// Step worker `name` once, tracing the outcome. Scenario scripts
    /// use this for hand-crafted interleavings; [`Self::run_until_complete`]
    /// drives random ones.
    pub fn step_worker(&mut self, name: &str) -> Result<WorkerEvent> {
        let idx = self
            .workers
            .iter()
            .position(|s| s.name == name && s.alive)
            .ok_or_else(|| Error::Job(format!("sim: no live worker named {name:?}")))?;
        self.step_slot(idx)
    }

    fn step_slot(&mut self, idx: usize) -> Result<WorkerEvent> {
        let event = self.workers[idx].worker.step()?;
        let name = self.workers[idx].name.clone();
        let line = match &event {
            WorkerEvent::Idle => format!("{name} idle"),
            WorkerEvent::JobComplete => format!("{name} sees job complete"),
            WorkerEvent::Completed { job, chunk, duplicate } => format!(
                "{name} completed {}#{chunk}{}",
                self.alias(job),
                if *duplicate { " (dup)" } else { "" }
            ),
            WorkerEvent::Rejected { job, chunk } => {
                format!("{name} rejected {}#{chunk}", self.alias(job))
            }
            WorkerEvent::Crashed { job, chunk } => {
                format!("{name} crashed holding {}#{chunk}", self.alias(job))
            }
            WorkerEvent::Disconnected => format!("{name} disconnected"),
            WorkerEvent::BudgetExhausted => format!("{name} budget exhausted"),
        };
        self.record(line);
        match &event {
            WorkerEvent::Crashed { .. }
            | WorkerEvent::JobComplete
            | WorkerEvent::BudgetExhausted => self.workers[idx].alive = false,
            _ => {}
        }
        Ok(event)
    }

    /// Sum of accepted chunks across all workers (chunk-conservation
    /// assertions).
    pub fn total_chunks_completed(&self) -> u64 {
        self.workers.iter().map(|s| s.worker.report().chunks).sum()
    }

    /// Names of workers still alive (not crashed/killed/finished).
    pub fn live_workers(&self) -> Vec<String> {
        self.workers
            .iter()
            .filter(|s| s.alive)
            .map(|s| s.name.clone())
            .collect()
    }

    /// Drive randomly-interleaved worker steps (seeded) until the
    /// job's journal holds its DONE record, advancing the clock by
    /// [`Self::idle_poll`] whenever a full round of live workers found
    /// nothing to do (which is also what lets an expired lease free a
    /// crashed worker's chunk). Errors after `max_steps` or if every
    /// worker died with the job incomplete.
    pub fn run_until_complete(&mut self, id: &str, max_steps: u64) -> Result<JobValue> {
        let store = self.store();
        let mut idle_streak = 0usize;
        for _ in 0..max_steps {
            let status = store.status(id)?;
            if status.complete {
                self.record(format!("{} complete", self.alias(id)));
                return status
                    .value
                    .ok_or_else(|| Error::Job("complete job lost its value".into()));
            }
            let live: Vec<usize> = self
                .workers
                .iter()
                .enumerate()
                .filter(|(_, s)| s.alive)
                .map(|(i, _)| i)
                .collect();
            if live.is_empty() {
                return Err(Error::Job(format!(
                    "sim: job {} incomplete but no live workers remain",
                    self.alias(id)
                )));
            }
            let pick = live[self.rng.usize_below(live.len())];
            match self.step_slot(pick)? {
                WorkerEvent::Idle | WorkerEvent::Disconnected => {
                    idle_streak += 1;
                    if idle_streak >= live.len() {
                        self.clock.advance(self.idle_poll);
                        idle_streak = 0;
                    }
                }
                _ => idle_streak = 0,
            }
        }
        Err(Error::Job(format!(
            "sim: job {} did not complete within {max_steps} steps",
            self.alias(id)
        )))
    }
}

/// What one seeded random scenario produced (see
/// [`run_random_scenario`]).
pub struct ScenarioOutcome {
    /// The composed determinant the fleet landed on.
    pub value: JobValue,
    /// The full replayable event trace.
    pub trace: Vec<String>,
    /// The same trace as JSON Lines (see [`SimWorld::trace_jsonl`]) —
    /// what `raddet sim --trace-json <path>` writes.
    pub trace_jsonl: String,
    /// Chunks in the job's plan.
    pub chunks_total: u64,
    /// Chunks accepted (non-duplicate) across all workers.
    pub fleet_chunks: u64,
    /// Whether message faults (drops/latency) were enabled for this
    /// seed — when `false`, `fleet_chunks == chunks_total` is an exact
    /// invariant (chunk conservation); under reply drops a journaled
    /// chunk's ack can be lost, so only `≤` holds.
    pub faulty: bool,
}

/// Extra scenario knobs for [`run_random_scenario_with`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ScenarioOptions {
    /// Route the server's journal/lock I/O through a seeded
    /// [`FaultFs`] (the [`FaultConfig::hostile`] mix: torn writes,
    /// fsync failures and lies, `ENOSPC`, read bitflips), armed after
    /// the submit round-trip. Server stops become power losses that
    /// drop un-fsynced bytes.
    pub disk_faults: bool,
}

/// The canonical seeded random scenario, shared by the
/// `tests/sim_seeds.rs` sweep and the `raddet sim` CLI so a failing
/// sweep seed is reproduced (trace and all) by
/// `raddet sim --seed <N>`.
///
/// From `seed` alone it derives: worker count (2–4), an optional
/// crash-after-k-grants worker, message faults on odd seeds (latency +
/// drops), and a random interleaving of worker steps, partitions,
/// server restarts and clock advances, run to job completion.
pub fn run_random_scenario(
    seed: u64,
    payload: JobPayload,
    engine: JobEngine,
    cfg: FleetConfig,
    dir: impl Into<PathBuf>,
) -> Result<ScenarioOutcome> {
    run_random_scenario_with(seed, payload, engine, cfg, dir, ScenarioOptions::default())
}

/// [`run_random_scenario`] with extra fault layers — disk + network +
/// clock under the one seed. The recovery contract the disk-fault
/// sweep asserts: every schedule either converges to the reference
/// bits or returns a **typed error** after which `fsck --repair` plus
/// a local resume still lands on the reference bits (never a panic,
/// never silent corruption).
pub fn run_random_scenario_with(
    seed: u64,
    payload: JobPayload,
    engine: JobEngine,
    cfg: FleetConfig,
    dir: impl Into<PathBuf>,
    options: ScenarioOptions,
) -> Result<ScenarioOutcome> {
    let disk = options.disk_faults.then(FaultConfig::hostile);
    let mut world = SimWorld::new_with_disk(seed, dir, cfg, disk);
    let mut rng = TestRng::from_seed(seed ^ 0xA5A5_5A5A);

    let id = world.submit_fleet(payload, engine)?;
    // Odd seeds get message faults; even seeds stay clean so exact
    // chunk conservation can be asserted for them. Enabled only after
    // the submit round-trip: the scenario explores *fleet* fault
    // tolerance, not whether the control client retries a submit.
    // Disk faults likewise arm only now, so the job exists on disk
    // before the storage layer turns hostile.
    let faulty = seed % 2 == 1;
    if faulty {
        world.set_faults(FaultPlan {
            latency: Duration::from_millis(rng.u64_below(4)),
            drop_per_10k: 100 + rng.u64_below(200) as u32,
        });
    }
    world.arm_disk(true);
    let n_workers = 2 + rng.u64_below(3); // 2..=4
    let crasher = rng.u64_below(2) == 0;
    for i in 0..n_workers {
        let name = format!("w{i}");
        let crash = (crasher && i == 0).then(|| 1 + rng.u64_below(3));
        world.add_worker(&name, |wc| {
            wc.job = Some(id.clone());
            wc.crash_after_grants = crash;
        })?;
    }

    let mut partitioned: HashSet<String> = HashSet::new();
    let mut idle_streak = 0usize;
    let mut rescues = 0u32;
    let mut ops = 0u64;
    let chunks_total = loop {
        let status = world.store().status(&id)?;
        if status.complete {
            break status.chunks_total as u64;
        }
        ops += 1;
        if ops >= 20_000 {
            return Err(Error::Job(format!(
                "seed {seed}: scenario failed to converge within {ops} ops"
            )));
        }
        let mut live = world.live_workers();
        if live.is_empty() {
            // Every worker died (crash injection / retry exhaustion
            // under heavy faults): heal the world and send in a rescue
            // worker, like an operator would.
            for p in partitioned.drain() {
                world.heal(&p);
            }
            rescues += 1;
            let name = format!("rescue{rescues}");
            world.add_worker(&name, |wc| {
                wc.job = Some(id.clone());
            })?;
            live = vec![name];
        }
        match rng.u64_below(100) {
            // Rare: server restart mid-sweep.
            0..=1 => world.restart_server(),
            // Occasional partition flap of one worker.
            2..=4 => {
                let w = live[rng.usize_below(live.len())].clone();
                if partitioned.contains(&w) {
                    world.heal(&w);
                    partitioned.remove(&w);
                } else {
                    world.partition(&w);
                    partitioned.insert(w);
                }
            }
            // Let virtual time pass (TTL pressure).
            5..=9 => world.advance(Duration::from_millis(30)),
            // Otherwise: step a random live worker.
            _ => {
                let w = live[rng.usize_below(live.len())].clone();
                match world.step_worker(&w) {
                    Ok(WorkerEvent::Idle) | Ok(WorkerEvent::Disconnected) => {
                        idle_streak += 1;
                        if idle_streak >= live.len() {
                            world.advance(Duration::from_millis(50));
                            idle_streak = 0;
                        }
                    }
                    Ok(_) => idle_streak = 0,
                    // Retry budget exhausted (long partition window):
                    // that worker is dead; the loop rescues if needed.
                    Err(_) => world.kill_worker(&w),
                }
            }
        }
    };

    let status = world.store().status(&id)?;
    let value = status
        .value
        .ok_or_else(|| Error::Job("complete job lost its value".into()))?;
    Ok(ScenarioOutcome {
        value,
        trace: world.trace(),
        trace_jsonl: world.trace_jsonl(),
        chunks_total,
        // A lost completion ack (reply drop) or a journal append undone
        // by a power loss after an fsync lie both break exact ack
        // conservation, so disk faults mark the outcome faulty too.
        fleet_chunks: world.total_chunks_completed(),
        faulty: faulty || options.disk_faults,
    })
}
