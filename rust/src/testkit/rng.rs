//! SplitMix64-based deterministic RNG for tests and benches.
//!
//! SplitMix64 (Steele, Lea, Flood 2014) passes BigCrush for this use and
//! is 5 lines — the right tool given `rand` is unavailable offline.

/// Deterministic test RNG (SplitMix64 core).
#[derive(Clone, Debug)]
pub struct TestRng {
    state: u64,
}

impl TestRng {
    /// Construct from an explicit seed (replayable).
    pub fn from_seed(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`; `bound` must be non-zero.
    /// Uses Lemire's multiply-shift rejection for unbiasedness.
    pub fn u64_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "u64_below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[0, bound)`.
    pub fn usize_below(&mut self, bound: usize) -> usize {
        self.u64_below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64_unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64_unit() * (hi - lo)
    }

    /// Uniform i64 in `[lo, hi]` (inclusive).
    pub fn i64_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.u64_below((hi - lo + 1) as u64) as i64
    }

    /// Random u128 below `bound` (for rank sampling; bound > 0).
    pub fn u128_below(&mut self, bound: u128) -> u128 {
        assert!(bound > 0, "u128_below(0)");
        if bound <= u64::MAX as u128 {
            return self.u64_below(bound as u64) as u128;
        }
        // Rejection sample from 128 random bits.
        let zeros = bound.leading_zeros();
        loop {
            let x = ((self.next_u64() as u128) << 64 | self.next_u64() as u128) >> zeros;
            if x < bound {
                return x;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = TestRng::from_seed(42);
        let mut b = TestRng::from_seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut rng = TestRng::from_seed(1);
        for _ in 0..10_000 {
            assert!(rng.u64_below(7) < 7);
        }
    }

    #[test]
    fn unit_floats_in_range() {
        let mut rng = TestRng::from_seed(2);
        for _ in 0..10_000 {
            let x = rng.f64_unit();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn u64_below_roughly_uniform() {
        let mut rng = TestRng::from_seed(3);
        let mut counts = [0u32; 8];
        for _ in 0..80_000 {
            counts[rng.u64_below(8) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "bucket count {c} out of range");
        }
    }

    #[test]
    fn u128_below_large_bound() {
        let mut rng = TestRng::from_seed(4);
        let bound = u128::MAX / 3;
        for _ in 0..1_000 {
            assert!(rng.u128_below(bound) < bound);
        }
    }

    #[test]
    fn i64_range_inclusive() {
        let mut rng = TestRng::from_seed(5);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let v = rng.i64_range(-3, 3);
            assert!((-3..=3).contains(&v));
            saw_lo |= v == -3;
            saw_hi |= v == 3;
        }
        assert!(saw_lo && saw_hi);
    }
}
