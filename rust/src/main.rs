//! raddet CLI entry point — see [`raddet::cli`] for the command set.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(raddet::cli::run(&args));
}
