//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (thiserror is unavailable in the
//! offline build — same substitution policy as bench/testkit/cli::args).

use crate::xla;

/// Unified error for all raddet subsystems.
#[derive(Debug)]
pub enum Error {
    /// Combinatorial argument out of range (e.g. `m > n`, rank ≥ C(n,m)).
    Combinatorics(String),

    /// Binomial/rank arithmetic would overflow u128.
    BinomialOverflow {
        /// Binomial upper argument.
        n: u64,
        /// Binomial lower argument.
        k: u64,
    },

    /// Job too large for enumeration (guard, see DESIGN.md §5).
    JobTooLarge {
        /// Matrix columns.
        n: u64,
        /// Matrix rows.
        m: u64,
        /// Term count C(n,m).
        total: u128,
        /// Configured cap.
        cap: u128,
    },

    /// Matrix shape problem.
    Shape(String),

    /// Artifact manifest / file problem.
    Artifact(String),

    /// No artifact bucket matches the request.
    NoArtifact {
        /// Requested submatrix order.
        m: usize,
        /// Requested dtype.
        dtype: &'static str,
        /// Buckets actually present.
        available: String,
    },

    /// PJRT / XLA runtime failure.
    Xla(String),

    /// Scalar arithmetic exceeded its range (e.g. an `i128` Bareiss
    /// intermediate): a typed refusal, never a silently wrapped wrong
    /// determinant. `--scalar big` removes the range entirely.
    ScalarOverflow {
        /// The computation that overflowed (`bareiss`, `radic sum`, …).
        what: &'static str,
        /// First rank of the offending chunk, attached by the chunk
        /// executor when the overflow happened inside a lease.
        chunk: Option<u128>,
    },

    /// Service protocol violation.
    Protocol(String),

    /// Durable job / journal problem (unknown id, concurrent-run
    /// conflict, malformed spec).
    Job(String),

    /// A job journal is damaged beyond the torn-tail tolerance: an
    /// *interior* record failed its checksum or structural validation.
    /// Typed (never a panic) so operators and the recovery invariant
    /// can route it to `raddet job fsck --repair`, which salvages the
    /// longest valid prefix and quarantines the rest.
    JournalCorrupt {
        /// 1-based record ordinal (the SPEC record is 1; the magic
        /// header line is not a record).
        record: usize,
        /// What failed — checksum mismatch, unparseable body, duplicate
        /// SPEC, out-of-plan chunk index, …
        cause: String,
    },

    /// I/O error.
    Io(std::io::Error),

    /// Configuration error (CLI or coordinator).
    Config(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Combinatorics(s) => write!(f, "combinatorics: {s}"),
            Error::BinomialOverflow { n, k } => {
                write!(f, "binomial overflow: C({n},{k}) exceeds u128")
            }
            Error::JobTooLarge { n, m, total, cap } => write!(
                f,
                "job too large: C({n},{m}) = {total} exceeds the enumeration cap {cap}"
            ),
            Error::Shape(s) => write!(f, "shape: {s}"),
            Error::Artifact(s) => write!(f, "artifact: {s}"),
            Error::NoArtifact { m, dtype, available } => write!(
                f,
                "no artifact for m={m} dtype={dtype}; available: {available}"
            ),
            Error::Xla(s) => write!(f, "xla: {s}"),
            Error::ScalarOverflow { what, chunk } => {
                write!(f, "scalar overflow in {what}")?;
                if let Some(start) = chunk {
                    write!(f, " (chunk starting at rank {start})")?;
                }
                Ok(())
            }
            Error::Protocol(s) => write!(f, "protocol: {s}"),
            Error::Job(s) => write!(f, "job: {s}"),
            Error::JournalCorrupt { record, cause } => {
                write!(f, "journal corrupt at record {record}: {cause}")
            }
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Config(s) => write!(f, "config: {s}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_previous_derive_format() {
        assert_eq!(
            Error::Combinatorics("m > n".into()).to_string(),
            "combinatorics: m > n"
        );
        assert_eq!(
            Error::BinomialOverflow { n: 200, k: 100 }.to_string(),
            "binomial overflow: C(200,100) exceeds u128"
        );
        assert_eq!(
            Error::ScalarOverflow { what: "bareiss", chunk: None }.to_string(),
            "scalar overflow in bareiss"
        );
        assert_eq!(
            Error::ScalarOverflow { what: "radic sum", chunk: Some(37) }.to_string(),
            "scalar overflow in radic sum (chunk starting at rank 37)"
        );
        assert_eq!(
            Error::JournalCorrupt { record: 3, cause: "checksum mismatch".into() }.to_string(),
            "journal corrupt at record 3: checksum mismatch"
        );
    }

    #[test]
    fn io_source_is_preserved() {
        use std::error::Error as _;
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.source().is_some());
        assert!(e.to_string().starts_with("io: "));
    }
}
