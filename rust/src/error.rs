//! Crate-wide error type.

/// Unified error for all raddet subsystems.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Combinatorial argument out of range (e.g. `m > n`, rank ≥ C(n,m)).
    #[error("combinatorics: {0}")]
    Combinatorics(String),

    /// Binomial/rank arithmetic would overflow u128.
    #[error("binomial overflow: C({n},{k}) exceeds u128")]
    BinomialOverflow { n: u64, k: u64 },

    /// Job too large for enumeration (guard, see DESIGN.md §5).
    #[error("job too large: C({n},{m}) = {total} exceeds the enumeration cap {cap}")]
    JobTooLarge { n: u64, m: u64, total: u128, cap: u128 },

    /// Matrix shape problem.
    #[error("shape: {0}")]
    Shape(String),

    /// Artifact manifest / file problem.
    #[error("artifact: {0}")]
    Artifact(String),

    /// No artifact bucket matches the request.
    #[error("no artifact for m={m} dtype={dtype}; available: {available}")]
    NoArtifact { m: usize, dtype: &'static str, available: String },

    /// PJRT / XLA runtime failure.
    #[error("xla: {0}")]
    Xla(String),

    /// Exact (integer) arithmetic overflow.
    #[error("exact arithmetic overflow in {0}")]
    ExactOverflow(&'static str),

    /// Service protocol violation.
    #[error("protocol: {0}")]
    Protocol(String),

    /// I/O error.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    /// Configuration error (CLI or coordinator).
    #[error("config: {0}")]
    Config(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
