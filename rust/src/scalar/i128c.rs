//! The fixed-width exact scalar: `i128` with every ring op checked.
//!
//! This is the pre-tower `exact` path, hardened: where the old twin
//! stack could (in principle) wrap in release builds at any raw
//! arithmetic site, every add/sub/mul here goes through the standard
//! library's checked ops and surfaces [`crate::Error::ScalarOverflow`]
//! — a loud, typed refusal instead of a silently wrong determinant.
//! Workloads whose intermediates exceed `i128` belong on
//! [`super::BigInt`] (`--scalar big`).

use super::{overflow, Scalar, ScalarKind};
use crate::{Error, Result};

impl Scalar for i128 {
    type Elem = i64;
    /// Running checked sum (the value itself).
    type Accum = i128;

    const KIND: ScalarKind = ScalarKind::I128;

    fn from_elem(e: i64) -> i128 {
        e as i128
    }

    fn zero() -> i128 {
        0
    }

    fn one() -> i128 {
        1
    }

    fn is_zero(&self) -> bool {
        *self == 0
    }

    fn neg_checked(&self, what: &'static str) -> Result<i128> {
        // The one asymmetric edge of two's complement: −i128::MIN does
        // not exist. A wrapped sign flip would be a *wrong journaled
        // partial*, so this is checked like every other op.
        i128::checked_neg(*self).ok_or_else(|| overflow(what))
    }

    fn add_checked(&self, rhs: &i128, what: &'static str) -> Result<i128> {
        i128::checked_add(*self, *rhs).ok_or_else(|| overflow(what))
    }

    fn sub_checked(&self, rhs: &i128, what: &'static str) -> Result<i128> {
        i128::checked_sub(*self, *rhs).ok_or_else(|| overflow(what))
    }

    fn mul_checked(&self, rhs: &i128, what: &'static str) -> Result<i128> {
        i128::checked_mul(*self, *rhs).ok_or_else(|| overflow(what))
    }

    fn div_exact(&self, rhs: &i128) -> i128 {
        debug_assert!(*rhs != 0 && *self % *rhs == 0, "inexact Bareiss division");
        *self / *rhs
    }

    fn accum_new() -> i128 {
        0
    }

    fn accum_add(acc: &mut i128, x: &i128, what: &'static str) -> Result<()> {
        *acc = i128::checked_add(*acc, *x).ok_or_else(|| overflow(what))?;
        Ok(())
    }

    fn accum_value(acc: &i128) -> i128 {
        *acc
    }

    fn encode(&self) -> String {
        format!("i128:{self}")
    }

    fn decode(tok: &str) -> Result<i128> {
        let dec = tok
            .strip_prefix("i128:")
            .ok_or_else(|| Error::Job(format!("bad i128 value {tok:?}")))?;
        dec.parse()
            .map_err(|e| Error::Job(format!("bad i128 value {tok:?}: {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overflow_is_a_typed_error_not_a_wrap() {
        let max = i128::MAX;
        assert!(matches!(
            max.add_checked(&1, "t"),
            Err(Error::ScalarOverflow { what: "t", .. })
        ));
        assert!(matches!(max.mul_checked(&2, "t"), Err(Error::ScalarOverflow { .. })));
        assert!(matches!(
            i128::MIN.sub_checked(&1, "t"),
            Err(Error::ScalarOverflow { .. })
        ));
        let mut acc = i128::MAX;
        assert!(<i128 as Scalar>::accum_add(&mut acc, &1, "t").is_err());
        // Negation is checked too: −i128::MIN does not exist.
        assert!(matches!(
            i128::MIN.neg_checked("t"),
            Err(Error::ScalarOverflow { .. })
        ));
        assert_eq!(i128::MAX.neg_checked("t").unwrap(), -i128::MAX);
    }

    #[test]
    fn encoding_roundtrips_extremes() {
        for v in [0i128, -1, 42, i128::MAX, i128::MIN] {
            assert_eq!(<i128 as Scalar>::decode(&v.encode()).unwrap(), v);
        }
        assert!(<i128 as Scalar>::decode("i128:nope").is_err());
        assert!(<i128 as Scalar>::decode("big:1").is_err());
    }
}
