//! Dependency-free arbitrary-precision signed integers.
//!
//! Sign + little-endian `u64` limb magnitude, normalized (no trailing
//! zero limbs; zero is the empty magnitude with a positive sign). The
//! op set is exactly what generic Bareiss and the Radić accumulation
//! need — add, sub, mul, exact division, decimal I/O — implemented with
//! schoolbook algorithms plus bitwise long division: at determinant
//! sizes (hundreds to a few thousand bits) the O(bits·limbs) division
//! is far from any hot path, and simple code that is obviously correct
//! beats Knuth's algorithm D in a crate that must stay dependency-free
//! and auditable.
//!
//! The struct upholds one invariant everywhere: **always normalized**.
//! `PartialEq`/`Eq` derive correctly because of it.

use super::{Scalar, ScalarKind};
use crate::{Error, Result};
use std::cmp::Ordering;

/// An arbitrary-precision signed integer (see module docs).
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct BigInt {
    /// True for strictly negative values (never set on zero).
    negative: bool,
    /// Little-endian base-2⁶⁴ magnitude, no trailing zero limbs.
    mag: Vec<u64>,
}

/// 10¹⁹ — the largest power of ten in a `u64`, the radix the decimal
/// converter works one chunk at a time in.
const POW10_19: u64 = 10_000_000_000_000_000_000;

fn norm(mut mag: Vec<u64>) -> Vec<u64> {
    while mag.last() == Some(&0) {
        mag.pop();
    }
    mag
}

fn cmp_mag(a: &[u64], b: &[u64]) -> Ordering {
    if a.len() != b.len() {
        return a.len().cmp(&b.len());
    }
    for (x, y) in a.iter().rev().zip(b.iter().rev()) {
        if x != y {
            return x.cmp(y);
        }
    }
    Ordering::Equal
}

fn add_mag(a: &[u64], b: &[u64]) -> Vec<u64> {
    let (long, short) = if a.len() >= b.len() { (a, b) } else { (b, a) };
    let mut out = Vec::with_capacity(long.len() + 1);
    let mut carry = 0u128;
    for (i, &x) in long.iter().enumerate() {
        let y = short.get(i).copied().unwrap_or(0);
        let s = x as u128 + y as u128 + carry;
        out.push(s as u64);
        carry = s >> 64;
    }
    if carry != 0 {
        out.push(carry as u64);
    }
    norm(out)
}

/// `a − b` for `a ≥ b` (callers order the operands first).
fn sub_mag(a: &[u64], b: &[u64]) -> Vec<u64> {
    debug_assert!(cmp_mag(a, b) != Ordering::Less);
    let mut out = Vec::with_capacity(a.len());
    let mut borrow = 0u64;
    for (i, &x) in a.iter().enumerate() {
        let y = b.get(i).copied().unwrap_or(0);
        let (d1, b1) = x.overflowing_sub(y);
        let (d2, b2) = d1.overflowing_sub(borrow);
        out.push(d2);
        borrow = (b1 || b2) as u64;
    }
    debug_assert_eq!(borrow, 0, "sub_mag requires a >= b");
    norm(out)
}

fn mul_mag(a: &[u64], b: &[u64]) -> Vec<u64> {
    if a.is_empty() || b.is_empty() {
        return Vec::new();
    }
    let mut out = vec![0u64; a.len() + b.len()];
    for (i, &xi) in a.iter().enumerate() {
        let mut carry = 0u128;
        for (j, &yj) in b.iter().enumerate() {
            let cur = out[i + j] as u128 + xi as u128 * yj as u128 + carry;
            out[i + j] = cur as u64;
            carry = cur >> 64;
        }
        let mut k = i + b.len();
        while carry != 0 {
            let cur = out[k] as u128 + carry;
            out[k] = cur as u64;
            carry = cur >> 64;
            k += 1;
        }
    }
    norm(out)
}

fn bit_len(mag: &[u64]) -> usize {
    match mag.last() {
        None => 0,
        Some(&top) => 64 * (mag.len() - 1) + (64 - top.leading_zeros() as usize),
    }
}

fn get_bit(mag: &[u64], i: usize) -> u64 {
    (mag[i / 64] >> (i % 64)) & 1
}

/// `r = (r << 1) | bit` in place.
fn shl1_or(r: &mut Vec<u64>, bit: u64) {
    let mut carry = bit;
    for limb in r.iter_mut() {
        let next = *limb >> 63;
        *limb = (*limb << 1) | carry;
        carry = next;
    }
    if carry != 0 {
        r.push(carry);
    }
}

/// Magnitude `(quotient, remainder)` by bitwise long division
/// (`d` non-empty).
fn divmod_mag(n: &[u64], d: &[u64]) -> (Vec<u64>, Vec<u64>) {
    debug_assert!(!d.is_empty(), "division by zero");
    if cmp_mag(n, d) == Ordering::Less {
        return (Vec::new(), n.to_vec());
    }
    let mut q = vec![0u64; n.len()];
    let mut r: Vec<u64> = Vec::new();
    for i in (0..bit_len(n)).rev() {
        shl1_or(&mut r, get_bit(n, i));
        if cmp_mag(&r, d) != Ordering::Less {
            r = sub_mag(&r, d);
            q[i / 64] |= 1 << (i % 64);
        }
    }
    (norm(q), r)
}

/// Magnitude `(quotient, remainder)` for a single-limb divisor.
fn divmod_small(mag: &[u64], d: u64) -> (Vec<u64>, u64) {
    let mut out = vec![0u64; mag.len()];
    let mut rem = 0u128;
    for (i, &limb) in mag.iter().enumerate().rev() {
        let cur = (rem << 64) | limb as u128;
        out[i] = (cur / d as u128) as u64;
        rem = cur % d as u128;
    }
    (norm(out), rem as u64)
}

/// `mag = mag · mul + add` in place (single-limb operands).
fn mul_small_add(mag: &mut Vec<u64>, mul: u64, add: u64) {
    let mut carry = add as u128;
    for limb in mag.iter_mut() {
        let cur = *limb as u128 * mul as u128 + carry;
        *limb = cur as u64;
        carry = cur >> 64;
    }
    while carry != 0 {
        mag.push(carry as u64);
        carry >>= 64;
    }
    while mag.last() == Some(&0) {
        mag.pop();
    }
}

impl BigInt {
    fn build(negative: bool, mag: Vec<u64>) -> BigInt {
        let mag = norm(mag);
        BigInt { negative: negative && !mag.is_empty(), mag }
    }

    /// From a matrix element.
    pub fn from_i64(v: i64) -> BigInt {
        BigInt::from_i128(v as i128)
    }

    /// From an `i128` (lossless).
    pub fn from_i128(v: i128) -> BigInt {
        let u = v.unsigned_abs();
        BigInt::build(v < 0, vec![u as u64, (u >> 64) as u64])
    }

    /// Back to `i128` when the value fits, else `None`.
    pub fn to_i128(&self) -> Option<i128> {
        if self.mag.len() > 2 {
            return None;
        }
        let lo = self.mag.first().copied().unwrap_or(0) as u128;
        let hi = self.mag.get(1).copied().unwrap_or(0) as u128;
        let u = (hi << 64) | lo;
        if self.negative {
            match u.cmp(&(1u128 << 127)) {
                Ordering::Greater => None,
                Ordering::Equal => Some(i128::MIN),
                Ordering::Less => Some(-(u as i128)),
            }
        } else if u > i128::MAX as u128 {
            None
        } else {
            Some(u as i128)
        }
    }

    /// Parse a decimal string (optional leading `-`).
    pub fn from_decimal(s: &str) -> Result<BigInt> {
        let (negative, digits) = match s.strip_prefix('-') {
            Some(rest) => (true, rest),
            None => (false, s),
        };
        if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
            return Err(Error::Job(format!("bad big value {s:?}")));
        }
        let mut mag: Vec<u64> = Vec::new();
        let bytes = digits.as_bytes();
        let mut i = 0;
        while i < bytes.len() {
            let take = (bytes.len() - i).min(19);
            let chunk: u64 = digits[i..i + take]
                .parse()
                .expect("all-digit chunk of <= 19 digits fits u64");
            let radix = 10u64.pow(take as u32);
            mul_small_add(&mut mag, radix, chunk);
            i += take;
        }
        Ok(BigInt::build(negative, mag))
    }

    /// Magnitude comparison ignoring sign.
    fn cmp_abs(&self, other: &BigInt) -> Ordering {
        cmp_mag(&self.mag, &other.mag)
    }

    /// The additive inverse (total — big integers have no asymmetric
    /// edge, unlike two's complement).
    pub fn negated(&self) -> BigInt {
        BigInt::build(!self.negative, self.mag.clone())
    }

    fn add_signed(&self, rhs: &BigInt) -> BigInt {
        if self.negative == rhs.negative {
            return BigInt::build(self.negative, add_mag(&self.mag, &rhs.mag));
        }
        match self.cmp_abs(rhs) {
            Ordering::Equal => BigInt::default(),
            Ordering::Greater => BigInt::build(self.negative, sub_mag(&self.mag, &rhs.mag)),
            Ordering::Less => BigInt::build(rhs.negative, sub_mag(&rhs.mag, &self.mag)),
        }
    }
}

impl std::fmt::Display for BigInt {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.mag.is_empty() {
            return f.write_str("0");
        }
        // Peel base-10¹⁹ chunks off the magnitude, least significant
        // first, then print most-significant plain and the rest padded.
        let mut chunks: Vec<u64> = Vec::new();
        let mut cur = self.mag.clone();
        while !cur.is_empty() {
            let (q, rem) = divmod_small(&cur, POW10_19);
            chunks.push(rem);
            cur = q;
        }
        if self.negative {
            f.write_str("-")?;
        }
        let mut it = chunks.iter().rev();
        if let Some(first) = it.next() {
            write!(f, "{first}")?;
        }
        for chunk in it {
            write!(f, "{chunk:019}")?;
        }
        Ok(())
    }
}

impl Scalar for BigInt {
    type Elem = i64;
    /// Running exact sum (the value itself — addition cannot overflow).
    type Accum = BigInt;

    const KIND: ScalarKind = ScalarKind::Big;

    fn from_elem(e: i64) -> BigInt {
        BigInt::from_i64(e)
    }

    /// Reuses the existing limb buffer (an `i64` needs at most one
    /// limb), so engine scratch that is assigned in place stops paying
    /// one heap allocation per element per block.
    fn assign_elem(&mut self, e: i64) {
        self.negative = e < 0;
        self.mag.clear();
        let u = e.unsigned_abs();
        if u != 0 {
            self.mag.push(u);
        }
    }

    fn zero() -> BigInt {
        BigInt::default()
    }

    fn one() -> BigInt {
        BigInt { negative: false, mag: vec![1] }
    }

    fn is_zero(&self) -> bool {
        self.mag.is_empty()
    }

    fn neg_checked(&self, _what: &'static str) -> Result<BigInt> {
        Ok(self.negated())
    }

    fn add_checked(&self, rhs: &BigInt, _what: &'static str) -> Result<BigInt> {
        Ok(self.add_signed(rhs))
    }

    fn sub_checked(&self, rhs: &BigInt, _what: &'static str) -> Result<BigInt> {
        Ok(self.add_signed(&rhs.negated()))
    }

    fn mul_checked(&self, rhs: &BigInt, _what: &'static str) -> Result<BigInt> {
        Ok(BigInt::build(
            self.negative != rhs.negative,
            mul_mag(&self.mag, &rhs.mag),
        ))
    }

    fn div_exact(&self, rhs: &BigInt) -> BigInt {
        debug_assert!(!rhs.is_zero(), "division by zero");
        // Bareiss divides by the *previous pivot*: 1 on the first
        // elimination step and a single limb for the early steps of
        // most workloads — serve those with O(limbs) short division
        // and keep the bit-serial long division for genuinely
        // multi-limb divisors (simple and auditable over clever; see
        // module docs and benches/bench_scalar.rs).
        if rhs.mag == [1] {
            return BigInt::build(self.negative != rhs.negative, self.mag.clone());
        }
        let (q, r_is_zero) = if rhs.mag.len() == 1 {
            let (q, r) = divmod_small(&self.mag, rhs.mag[0]);
            (q, r == 0)
        } else {
            let (q, r) = divmod_mag(&self.mag, &rhs.mag);
            (q, r.is_empty())
        };
        debug_assert!(r_is_zero, "inexact Bareiss division");
        let _ = r_is_zero;
        BigInt::build(self.negative != rhs.negative, q)
    }

    fn accum_new() -> BigInt {
        BigInt::default()
    }

    fn accum_add(acc: &mut BigInt, x: &BigInt, _what: &'static str) -> Result<()> {
        *acc = acc.add_signed(x);
        Ok(())
    }

    fn accum_value(acc: &BigInt) -> BigInt {
        acc.clone()
    }

    fn encode(&self) -> String {
        format!("big:{self}")
    }

    fn decode(tok: &str) -> Result<BigInt> {
        let dec = tok
            .strip_prefix("big:")
            .ok_or_else(|| Error::Job(format!("bad big value {tok:?}")))?;
        BigInt::from_decimal(dec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn big(v: i128) -> BigInt {
        BigInt::from_i128(v)
    }

    #[test]
    fn i128_roundtrip_and_extremes() {
        for v in [0i128, 1, -1, 42, -99, i64::MAX as i128, i128::MAX, i128::MIN] {
            let b = big(v);
            assert_eq!(b.to_i128(), Some(v), "{v}");
            assert_eq!(b.to_string(), v.to_string());
            assert_eq!(BigInt::from_decimal(&v.to_string()).unwrap(), b);
        }
        // One past i128::MAX no longer fits.
        let over = big(i128::MAX).add_checked(&BigInt::one(), "t").unwrap();
        assert_eq!(over.to_i128(), None);
        assert_eq!(over.to_string(), "170141183460469231731687303715884105728");
        // i128::MIN − 1 doesn't either (the asymmetric edge).
        let under = big(i128::MIN).sub_checked(&BigInt::one(), "t").unwrap();
        assert_eq!(under.to_i128(), None);
        assert_eq!(under.to_string(), "-170141183460469231731687303715884105729");
    }

    #[test]
    fn signed_arithmetic_matches_i128_where_it_fits() {
        // Deterministic pseudo-random i64 pairs via an LCG: every
        // signed add/sub/mul agrees with native i128 arithmetic.
        let mut state = 0x243F_6A88_85A3_08D3u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 16) as i64 - (1i64 << 47)
        };
        for _ in 0..500 {
            let (x, y) = (next() as i128, next() as i128);
            let (bx, by) = (big(x), big(y));
            assert_eq!(bx.add_checked(&by, "t").unwrap(), big(x + y), "{x}+{y}");
            assert_eq!(bx.sub_checked(&by, "t").unwrap(), big(x - y), "{x}-{y}");
            assert_eq!(bx.mul_checked(&by, "t").unwrap(), big(x * y), "{x}*{y}");
            if y != 0 && x % y == 0 {
                assert_eq!(bx.div_exact(&by), big(x / y), "{x}/{y}");
            }
        }
    }

    #[test]
    fn multi_limb_mul_and_exact_division_invert() {
        // (a·b) / b == a well past one limb, all sign combinations.
        let magnitudes = [
            big(3),
            big(i64::MAX as i128),
            big(i128::MAX),
            BigInt::from_decimal("340282366920938463463374607431768211455123456789").unwrap(),
        ];
        for a in &magnitudes {
            for b in &magnitudes {
                for (sa, sb) in [(1, 1), (1, -1), (-1, 1), (-1, -1)] {
                    let a = if sa < 0 { a.negated() } else { a.clone() };
                    let b = if sb < 0 { b.negated() } else { b.clone() };
                    let p = a.mul_checked(&b, "t").unwrap();
                    assert_eq!(p.div_exact(&b), a, "{a:?} * {b:?}");
                }
            }
        }
    }

    #[test]
    fn decimal_io_roundtrips_large_values() {
        // 2^256-ish magnitudes in both signs, plus padding-sensitive
        // values whose middle base-10¹⁹ chunks are zero.
        for s in [
            "115792089237316195423570985008687907853269984665640564039457584007913129639936",
            "-115792089237316195423570985008687907853269984665640564039457584007913129639935",
            "10000000000000000000",
            "-10000000000000000000000000000000000000000",
            "20000000000000000000000000000000000000001",
            "0",
        ] {
            let b = BigInt::from_decimal(s).unwrap();
            assert_eq!(b.to_string(), s, "roundtrip {s}");
            assert_eq!(<BigInt as Scalar>::decode(&b.encode()).unwrap(), b);
        }
        for bad in ["", "-", "12x4", "1.5", "+7", "big:1"] {
            assert!(BigInt::from_decimal(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn zero_is_canonical() {
        // Every route to zero lands on the one normalized value.
        let zeros = [
            BigInt::default(),
            BigInt::zero(),
            big(5).sub_checked(&big(5), "t").unwrap(),
            big(-7).add_checked(&big(7), "t").unwrap(),
            big(0).negated(),
            big(123).mul_checked(&big(0), "t").unwrap(),
        ];
        for z in &zeros {
            assert!(z.is_zero());
            assert_eq!(z, &BigInt::zero());
            assert_eq!(z.to_string(), "0");
        }
    }
}
