//! The float scalar: plain IEEE-754 `f64` with Neumaier accumulation
//! and the bit-pattern wire encoding (docs/PROTOCOL.md §1.3).
//!
//! Float arithmetic has no overflow *error* — it saturates to ±inf —
//! so the checked ops are infallible; what the float path guarantees
//! instead is **bit determinism**: rank-ordered compensated
//! accumulation and a lossless encoding, which together make a resumed
//! or fleet-sharded sweep land on the identical 64 bits.

use super::{Scalar, ScalarKind};
use crate::linalg::NeumaierSum;
use crate::{Error, Result};

impl Scalar for f64 {
    type Elem = f64;
    type Accum = NeumaierSum;

    const KIND: ScalarKind = ScalarKind::F64;

    fn from_elem(e: f64) -> f64 {
        e
    }

    fn zero() -> f64 {
        0.0
    }

    fn one() -> f64 {
        1.0
    }

    fn is_zero(&self) -> bool {
        *self == 0.0
    }

    fn neg_checked(&self, _what: &'static str) -> Result<f64> {
        Ok(-*self)
    }

    fn add_checked(&self, rhs: &f64, _what: &'static str) -> Result<f64> {
        Ok(*self + *rhs)
    }

    fn sub_checked(&self, rhs: &f64, _what: &'static str) -> Result<f64> {
        Ok(*self - *rhs)
    }

    fn mul_checked(&self, rhs: &f64, _what: &'static str) -> Result<f64> {
        Ok(*self * *rhs)
    }

    fn div_exact(&self, rhs: &f64) -> f64 {
        *self / *rhs
    }

    fn accum_new() -> NeumaierSum {
        NeumaierSum::new()
    }

    fn accum_add(acc: &mut NeumaierSum, x: &f64, _what: &'static str) -> Result<()> {
        acc.add(*x);
        Ok(())
    }

    fn accum_value(acc: &NeumaierSum) -> f64 {
        acc.value()
    }

    fn encode(&self) -> String {
        format!("f64:{:016x}", self.to_bits())
    }

    fn decode(tok: &str) -> Result<f64> {
        let hex = tok
            .strip_prefix("f64:")
            .ok_or_else(|| Error::Job(format!("bad f64 value {tok:?}")))?;
        let bits = u64::from_str_radix(hex, 16)
            .map_err(|e| Error::Job(format!("bad f64 value {tok:?}: {e}")))?;
        Ok(f64::from_bits(bits))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encoding_is_bit_exact() {
        for v in [0.0f64, -0.0, 1.5, -2.75e-300, f64::INFINITY, f64::NAN] {
            let back = <f64 as Scalar>::decode(&v.encode()).unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "{}", v.encode());
        }
        assert!(<f64 as Scalar>::decode("f64:xyz").is_err());
        assert!(<f64 as Scalar>::decode("i128:1").is_err());
    }

    #[test]
    fn accumulation_is_neumaier() {
        // The canonical compensation example a naïve sum gets wrong.
        let mut acc = <f64 as Scalar>::accum_new();
        for x in [1.0f64, 1e100, 1.0, -1e100] {
            <f64 as Scalar>::accum_add(&mut acc, &x, "t").unwrap();
        }
        assert_eq!(<f64 as Scalar>::accum_value(&acc), 2.0);
    }
}
