//! The scalar tower — one arithmetic abstraction under every engine.
//!
//! The Radić sweep is arithmetic-agnostic: the C(n,m) rank-space
//! partition, prefix cofactor sharing and the chunk/lease fabric are
//! identical whether a term is evaluated in `f64`, `i128` or arbitrary
//! precision. This module is the one place that *difference* lives: a
//! sealed [`Scalar`] trait (ring ops, exact division for Bareiss,
//! canonical wire/journal encoding, accumulation rules) with three
//! implementations:
//!
//! * [`F64`] (= `f64`) — the float path. Ops are plain IEEE-754 (they
//!   saturate to ±inf rather than error); accumulation is
//!   Neumaier-compensated ([`crate::linalg::NeumaierSum`]), and the
//!   canonical encoding is the 16-hex-digit bit pattern of
//!   docs/PROTOCOL.md §1.3, so values round-trip bit-exactly.
//! * [`I128Checked`] (= `i128`) — the fixed-width exact path. Every
//!   add/sub/mul is checked: overflow surfaces as
//!   [`Error::ScalarOverflow`], never as a silently wrapped (wrong)
//!   determinant.
//! * [`BigInt`] — unbounded sign-and-magnitude integers (`Vec<u64>`
//!   limbs, dependency-free — the crate keeps its offline, zero-dep
//!   build). The overflow-proof scalar for production exact sweeps.
//!
//! Everything above — generic Bareiss and prefix cofactors
//! ([`crate::linalg`]), the generic chunk engine
//! ([`crate::coordinator::LeaseRunner`]), journal records, SPEC bodies
//! and `LEASE COMPLETE` values — is written once against this trait.
//! Adding a fourth scalar (rationals, f32 lanes, polynomial entries) is
//! one new file here plus a [`ScalarKind`] tag.
//!
//! The trait is **sealed**: the engine matrix, the wire grammar and the
//! journal format enumerate scalars by [`ScalarKind`], so out-of-crate
//! implementations could not be routed anyway.

mod bigint;
mod f64s;
mod i128c;

pub use bigint::BigInt;

use crate::{Error, Result};

/// `f64` is the float scalar (see [`Scalar`] docs).
pub type F64 = f64;
/// `i128` with every ring op checked is the fixed-width exact scalar.
pub type I128Checked = i128;

mod private {
    /// Seals [`super::Scalar`]: the scalar set is a closed enumeration
    /// (see module docs).
    pub trait Sealed {}
    impl Sealed for f64 {}
    impl Sealed for i128 {}
    impl Sealed for super::BigInt {}
}

/// The closed set of scalar arithmetics, as tagged on the wire and in
/// job journals (`SPEC` kind field, value-encoding prefixes).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ScalarKind {
    /// IEEE-754 double (compensated accumulation, bit-pattern encoding).
    F64,
    /// Checked 128-bit integers (overflow is a typed error).
    I128,
    /// Unbounded big integers (sign + `u64` limbs).
    Big,
}

impl ScalarKind {
    /// Canonical wire/journal tag: `f64`, `i128` or `big`.
    pub fn as_str(&self) -> &'static str {
        match self {
            ScalarKind::F64 => "f64",
            ScalarKind::I128 => "i128",
            ScalarKind::Big => "big",
        }
    }

    /// Parse a wire/journal tag. `exact` is accepted as an alias for
    /// `i128` — it is what pre-tower journals contain and what
    /// [`Self::wire_str`] still emits for them.
    pub fn parse(tok: &str) -> Result<ScalarKind> {
        match tok {
            "f64" => Ok(ScalarKind::F64),
            "i128" | "exact" => Ok(ScalarKind::I128),
            "big" => Ok(ScalarKind::Big),
            other => Err(Error::Job(format!("unknown scalar kind {other:?}"))),
        }
    }

    /// The tag *emitted* into SPEC records and `JOB SUBMIT` frames.
    /// For `i128` this stays the pre-tower spelling `exact`, so
    /// mixed-version fleets interoperate: an old worker can parse a
    /// new server's grants, and journals written by the new binary for
    /// i128 jobs replay on the old one. ([`Self::parse`] accepts both
    /// spellings either way; `f64`/`big` have no legacy form.)
    pub fn wire_str(&self) -> &'static str {
        match self {
            ScalarKind::I128 => "exact",
            other => other.as_str(),
        }
    }

}

impl std::fmt::Display for ScalarKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One value in the Radić sum — the arithmetic a sweep runs in.
///
/// The checked ops (`add_checked` / `sub_checked` / `mul_checked`)
/// return [`Error::ScalarOverflow`] when the scalar's range is
/// exceeded; scalars without a range ([`F64`] saturates, [`BigInt`]
/// grows) simply never fail them. `div_exact` is the Bareiss
/// fraction-free division: callers guarantee the quotient is exact, so
/// it is infallible (debug builds assert exactness).
///
/// Accumulation is part of the contract because it fixes the *bits* of
/// a chunk partial: [`F64`] accumulates with Neumaier compensation in
/// rank order, the exact scalars with (checked) integer addition. The
/// journal's bitwise-resume guarantee rests on every executor using
/// these rules and no others.
pub trait Scalar:
    private::Sealed + Clone + std::fmt::Debug + PartialEq + Send + Sync + 'static
{
    /// Matrix element type a job of this scalar carries (`f64` for the
    /// float path, `i64` for both exact paths).
    type Elem: Copy + std::fmt::Debug + PartialEq + Send + Sync + 'static;

    /// Deterministic running-sum state (Neumaier for `f64`, a checked
    /// running value for the exact scalars).
    type Accum: Send;

    /// The wire/journal tag this scalar answers to.
    const KIND: ScalarKind;

    /// Lift one matrix element into the scalar.
    fn from_elem(e: Self::Elem) -> Self;

    /// Overwrite `self` with one matrix element, reusing any owned
    /// allocation. The default just rebuilds; [`BigInt`] overrides it
    /// to keep its limb buffer's capacity — the lever that lets the
    /// exact engines' elimination scratch stop allocating per block
    /// (see `benches/bench_scalar.rs` §scratch).
    fn assign_elem(&mut self, e: Self::Elem) {
        *self = Self::from_elem(e);
    }

    /// Additive identity.
    fn zero() -> Self;

    /// Multiplicative identity.
    fn one() -> Self;

    /// Is this the additive identity?
    fn is_zero(&self) -> bool;

    /// Checked negation — checked like every other ring op because it
    /// is *not* total on two's-complement scalars (`-i128::MIN`
    /// overflows; unbounded and float scalars never fail).
    fn neg_checked(&self, what: &'static str) -> Result<Self>;

    /// Checked addition; `what` names the computation for the error.
    fn add_checked(&self, rhs: &Self, what: &'static str) -> Result<Self>;

    /// Checked subtraction.
    fn sub_checked(&self, rhs: &Self, what: &'static str) -> Result<Self>;

    /// Checked multiplication.
    fn mul_checked(&self, rhs: &Self, what: &'static str) -> Result<Self>;

    /// Exact division (Bareiss: the division is guaranteed exact).
    fn div_exact(&self, rhs: &Self) -> Self;

    /// Fresh accumulator.
    fn accum_new() -> Self::Accum;

    /// Fold one term into the accumulator (checked for exact scalars).
    fn accum_add(acc: &mut Self::Accum, x: &Self, what: &'static str) -> Result<()>;

    /// The accumulated value.
    fn accum_value(acc: &Self::Accum) -> Self;

    /// Canonical tagged wire/journal encoding (`f64:<16 hex>`,
    /// `i128:<decimal>`, `big:<decimal>`) — must round-trip exactly
    /// through [`Scalar::decode`].
    fn encode(&self) -> String;

    /// Decode the canonical encoding (rejects other scalars' tags).
    fn decode(tok: &str) -> Result<Self>;
}

/// The [`Error::ScalarOverflow`] constructor every checked op uses
/// (chunk attribution is added higher up, where the chunk is known).
pub(crate) fn overflow(what: &'static str) -> Error {
    Error::ScalarOverflow { what, chunk: None }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_tags_roundtrip_and_alias() {
        for kind in [ScalarKind::F64, ScalarKind::I128, ScalarKind::Big] {
            assert_eq!(ScalarKind::parse(kind.as_str()).unwrap(), kind);
        }
        // Legacy journals tag the i128 path "exact".
        assert_eq!(ScalarKind::parse("exact").unwrap(), ScalarKind::I128);
        assert!(ScalarKind::parse("f32").is_err());
        // The emitted tag stays wire-compatible with pre-tower peers,
        // and round-trips through parse for every kind.
        assert_eq!(ScalarKind::I128.wire_str(), "exact");
        for kind in [ScalarKind::F64, ScalarKind::I128, ScalarKind::Big] {
            assert_eq!(ScalarKind::parse(kind.wire_str()).unwrap(), kind);
        }
    }

    #[test]
    fn encodings_are_tag_disjoint() {
        let f = 1.5f64.encode();
        let i = 2i128.encode();
        let b = BigInt::from_i64(3).encode();
        assert!(f.starts_with("f64:"), "{f}");
        assert!(i.starts_with("i128:"), "{i}");
        assert!(b.starts_with("big:"), "{b}");
        // Cross-decoding must fail: a big job cannot silently accept an
        // i128-encoded partial and vice versa.
        assert!(<f64 as Scalar>::decode(&i).is_err());
        assert!(<i128 as Scalar>::decode(&b).is_err());
        assert!(<BigInt as Scalar>::decode(&f).is_err());
    }

    #[test]
    fn generic_ring_identities() {
        fn check<S: Scalar>(x: S) {
            assert_eq!(x.add_checked(&S::zero(), "t").unwrap(), x);
            assert_eq!(x.mul_checked(&S::one(), "t").unwrap(), x);
            assert_eq!(x.sub_checked(&x, "t").unwrap(), S::zero());
            assert!(S::zero().is_zero());
            let minus_x = x.neg_checked("t").unwrap();
            assert_eq!(minus_x.neg_checked("t").unwrap(), x);
            let mut acc = S::accum_new();
            S::accum_add(&mut acc, &x, "t").unwrap();
            S::accum_add(&mut acc, &minus_x, "t").unwrap();
            assert_eq!(S::accum_value(&acc), S::zero());
            assert_eq!(S::decode(&x.encode()).unwrap(), x);
        }
        check(-2.75f64);
        check(-41i128);
        check(BigInt::from_i128(-(1i128 << 100)));
    }
}
