//! # raddet — parallel Radić determinant of non-square matrices
//!
//! A reproduction of *“An Efficient Parallel Algorithm for Computing
//! Determinant of Non-Square Matrices Based on Radić's Definition”*
//! (Abdollahi, Jafari, Bayat, Amiri, Fathy — IJDPS 6(4), 2015).
//!
//! Radić's determinant of an `m×n` matrix (`m ≤ n`) is a signed sum over
//! all `C(n,m)` ascending column selections:
//!
//! ```text
//! det(A) = Σ_{1≤j1<…<jm≤n} (−1)^(r+s) · det(A[:, {j1…jm}])
//! r = m(m+1)/2,   s = j1+…+jm
//! ```
//!
//! The paper's contribution is an **unranking algorithm** (“combinatorial
//! addition”) that computes the `q`-th column combination in dictionary
//! order directly in `O(m·(n−m))`, removing the sequential dependency
//! between terms and making the sum embarrassingly parallel.
//!
//! ## Crate layout (three-layer architecture)
//!
//! * [`combin`] — the paper's §4/§5 algorithms: binomial tables, Pascal
//!   weight tables (Table 1/3), unranking (Fig. 1), ranking, successor
//!   generation, rank-range partitioning (granularity chunks).
//! * [`scalar`] — the scalar tower: one sealed [`scalar::Scalar`]
//!   trait (checked ring ops, Bareiss exact division, canonical wire
//!   encoding, accumulation rules) with `f64`, checked-`i128` and
//!   dependency-free big-integer implementations. Every engine,
//!   journal record and wire value above is generic over it.
//! * [`matrix`], [`linalg`] — substrates: dense matrices, deterministic
//!   generators, LU / Bareiss / Laplace determinants, and the sequential
//!   Radić reference implementation.
//! * [`runtime`] — PJRT client wrapper: loads the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes batched determinant
//!   graphs. Python never runs on this path. (Offline builds link the
//!   [`mod@xla`] stub, which fails loudly at runtime instead.)
//! * [`coordinator`] — the L3 system: engines (per-term LU lanes, XLA
//!   batches, and the prefix-factored Laplace engine that amortizes one
//!   m×(m−1) factorization across each sibling combination block),
//!   batcher, scheduler (static granularity per §5, work-stealing, and
//!   block-aligned variants), worker pool, compensated reduction,
//!   metrics.
//! * [`pram`] — CRCW/CREW/EREW cost-model simulator reproducing the §6
//!   complexity table.
//! * [`jobs`] — durable det-jobs: the rank space partitioned into
//!   block-aligned chunks, each completed chunk journaled (append-only,
//!   fsync'd, checksummed), interrupted sweeps resumed to a
//!   bitwise-identical result.
//! * [`service`] — TCP determinant service (the §8 “network overhead”
//!   future-work study), including `JOB` verbs over the jobs subsystem
//!   and the fleet `LEASE` verbs (`docs/PROTOCOL.md` is the normative
//!   wire spec).
//! * [`fleet`] — worker-fleet sharding: a server-side lease table
//!   grants block-aligned chunks of a durable job to remote
//!   `raddet worker` processes with TTL expiry and reassignment;
//!   journaled completions make the distributed result bitwise-equal
//!   to a single-process run (see `ARCHITECTURE.md`).
//! * [`apps`] — the paper's motivating application: image retrieval with
//!   a non-square determinant similarity kernel (refs \[8\], [20–23]).
//! * [`clock`] — the virtual-time seam: a [`clock::Clock`] trait with
//!   the production [`clock::WallClock`] and the manually-advanced
//!   [`clock::SimClock`] behind every TTL, heartbeat and wait deadline.
//! * [`retry`] — the unified seeded retry policy (exponential backoff
//!   with jitter over the [`clock`] seam) pacing every reconnect and
//!   idle loop; [`jobs::fs`] is the matching storage seam whose
//!   [`jobs::FaultFs`] faults the disk under the same scenario seed.
//! * [`telemetry`] — the observability layer: a dependency-free metrics
//!   registry (counters, gauges, fixed-bucket histograms) with a
//!   canonical text snapshot, plus a structured event log on the
//!   [`clock`] seam. Every server owns one registry; the `METRICS` /
//!   `METRICS JOB` wire verbs and `raddet job top` read it.
//! * [`mod@bench`], [`testkit`], [`cli`] — in-crate substrates replacing
//!   criterion / proptest / clap (offline environment, see DESIGN.md §2);
//!   [`testkit::sim`] is the deterministic simulation fabric (virtual
//!   clock + in-memory transport + seeded scheduler) the fleet's
//!   failure scenarios replay on.
//!
//! ## Quickstart
//!
//! ```no_run
//! use raddet::coordinator::{Coordinator, CoordinatorConfig, EngineKind};
//! use raddet::matrix::Mat;
//!
//! let a = Mat::from_rows(&[
//!     vec![1.0, 2.0, 3.0],
//!     vec![4.0, 5.0, 6.0],
//! ]);
//! let cfg = CoordinatorConfig::default();
//! let coord = Coordinator::new(cfg).unwrap();
//! let out = coord.radic_det(&a).unwrap();
//! println!("det = {}", out.det);
//! ```

// Every public item documents itself; CI turns rustdoc warnings into
// errors (`cargo doc --no-deps` with RUSTDOCFLAGS=-D warnings), so a
// new undocumented API fails the build there rather than rotting here.
#![warn(missing_docs)]

pub mod apps;
pub mod bench;
pub mod cli;
pub mod clock;
pub mod combin;
pub mod coordinator;
pub mod error;
pub mod fleet;
pub mod jobs;
pub mod linalg;
pub mod matrix;
pub mod pram;
pub mod retry;
pub mod runtime;
pub mod scalar;
pub mod service;
pub mod telemetry;
pub mod testkit;
pub mod xla;

pub use error::{Error, Result};
