//! Robust sample statistics for the bench harness, plus a tiny JSON
//! emitter (serde is unavailable offline) so bench binaries can write
//! machine-readable `BENCH_*.json` trajectories.

/// Summary statistics over timing samples (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    /// Sample count.
    pub n: usize,
    /// Median.
    pub median: f64,
    /// Mean.
    pub mean: f64,
    /// 10th percentile.
    pub p10: f64,
    /// 90th percentile.
    pub p90: f64,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// Median absolute deviation (robust spread).
    pub mad: f64,
}

impl Stats {
    /// Compute from raw samples.
    pub fn from_samples(samples: &[f64]) -> Self {
        if samples.is_empty() {
            return Self::default();
        }
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).expect("finite samples"));
        let n = s.len();
        let median = percentile_sorted(&s, 50.0);
        let mean = s.iter().sum::<f64>() / n as f64;
        let mut devs: Vec<f64> = s.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).expect("finite devs"));
        Self {
            n,
            median,
            mean,
            p10: percentile_sorted(&s, 10.0),
            p90: percentile_sorted(&s, 90.0),
            min: s[0],
            max: s[n - 1],
            mad: percentile_sorted(&devs, 50.0),
        }
    }

    /// Serialize as a JSON object (seconds; non-finite values → null).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"n\":{},\"median\":{},\"mean\":{},\"p10\":{},\"p90\":{},\"min\":{},\"max\":{},\"mad\":{}}}",
            self.n,
            json_f64(self.median),
            json_f64(self.mean),
            json_f64(self.p10),
            json_f64(self.p90),
            json_f64(self.min),
            json_f64(self.max),
            json_f64(self.mad),
        )
    }
}

/// A finite f64 as a JSON number, else `null`.
pub fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".into()
    }
}

/// Assemble `fields` (already-serialized `"key":value` pairs) into one
/// JSON object — enough structure for bench records without serde.
pub fn json_object(fields: &[(&str, String)]) -> String {
    let body: Vec<String> = fields
        .iter()
        .map(|(k, v)| format!("\"{k}\":{v}"))
        .collect();
    format!("{{{}}}", body.join(","))
}

/// Linear-interpolated percentile of a sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        let s = Stats::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.mad, 1.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let s = [0.0, 10.0];
        assert_eq!(percentile_sorted(&s, 50.0), 5.0);
        assert_eq!(percentile_sorted(&s, 10.0), 1.0);
    }

    #[test]
    fn unordered_input_ok() {
        let s = Stats::from_samples(&[5.0, 1.0, 3.0]);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
    }

    #[test]
    fn empty_is_default() {
        let s = Stats::from_samples(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn json_roundtrips_shape() {
        let s = Stats::from_samples(&[1.0, 2.0, 3.0]).to_json();
        assert!(s.starts_with('{') && s.ends_with('}'), "{s}");
        assert!(s.contains("\"n\":3") && s.contains("\"median\":2"), "{s}");
        assert_eq!(json_f64(f64::NAN), "null");
        assert_eq!(
            json_object(&[("a", "1".into()), ("b", "\"x\"".into())]),
            "{\"a\":1,\"b\":\"x\"}"
        );
    }

    #[test]
    fn robust_to_outlier() {
        let s = Stats::from_samples(&[1.0, 1.0, 1.0, 1.0, 100.0]);
        assert_eq!(s.median, 1.0);
        assert!(s.mean > 10.0, "mean is dragged, median is not");
    }
}
