//! Criterion-lite — the in-crate benchmark harness (criterion is not
//! available offline; see DESIGN.md §2 substitution 3).
//!
//! the bench runner runs warmup + timed samples of a closure and reports
//! robust statistics ([`stats`]); [`Table`] renders aligned markdown so
//! every bench binary prints rows that paste directly into
//! EXPERIMENTS.md.

pub mod stats;

pub use stats::Stats;

use std::time::{Duration, Instant};

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Warmup iterations (not measured).
    pub warmup: u32,
    /// Measured samples.
    pub samples: u32,
    /// Minimum total measured time; samples are added until reached.
    pub min_time: Duration,
    /// Hard cap on measurement time per benchmark.
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: 3,
            samples: 20,
            min_time: Duration::from_millis(200),
            max_time: Duration::from_secs(20),
        }
    }
}

/// A quick config for slow end-to-end benches.
impl BenchConfig {
    /// Few samples, generous cap — end-to-end jobs.
    pub fn slow() -> Self {
        Self {
            warmup: 1,
            samples: 5,
            min_time: Duration::from_millis(50),
            max_time: Duration::from_secs(60),
        }
    }
}

/// Run one benchmark: `f` is called per sample and may return a value
/// (black-boxed to defeat DCE). Returns per-sample durations.
pub fn bench<T, F: FnMut() -> T>(cfg: &BenchConfig, mut f: F) -> Stats {
    for _ in 0..cfg.warmup {
        std::hint::black_box(f());
    }
    let started = Instant::now();
    let mut samples = Vec::with_capacity(cfg.samples as usize);
    while (samples.len() < cfg.samples as usize || started.elapsed() < cfg.min_time)
        && started.elapsed() < cfg.max_time
    {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() >= 4 * cfg.samples as usize {
            break; // enough statistics even if min_time not reached
        }
    }
    Stats::from_samples(&samples)
}

/// Aligned markdown table builder for bench output.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with column headers.
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    /// Render as aligned markdown.
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..cols {
                s.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            s.push('\n');
            s
        };
        let mut out = fmt_row(&self.header);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&fmt_row(&sep));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let cfg = BenchConfig {
            warmup: 1,
            samples: 5,
            min_time: Duration::from_millis(1),
            max_time: Duration::from_secs(5),
        };
        let mut count = 0u64;
        let stats = bench(&cfg, || {
            count += 1;
            count
        });
        assert!(stats.n >= 5);
        assert!(stats.median >= 0.0);
        assert!(count >= 6, "warmup + samples");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("| name   | value |"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(5e-9).ends_with("ns"));
        assert!(fmt_time(5e-6).ends_with("µs"));
        assert!(fmt_time(5e-3).ends_with("ms"));
        assert!(fmt_time(5.0).ends_with("s"));
    }
}
