//! Worker fleet — sharding one durable det-job across processes.
//!
//! The paper's O(n²) bound assumes the `C(n,m)` term space is spread
//! across many processors; in-process parallelism tops out at one
//! machine. This subsystem distributes the same block-aligned chunks
//! the durable-jobs layer journals (see [`crate::jobs`]) across a fleet
//! of worker *processes* over the TCP service's `LEASE` verbs:
//!
//! ```text
//! server (raddet serve --jobs-dir D)          workers (raddet worker)
//! ┌──────────────────────────────┐            ┌──────────────────────┐
//! │ LeaseTable                   │← GRANT ────│ claim chunk, get spec│
//! │  chunk → free|leased|done    │─ OK LEASE →│ ChunkRunner::run_    │
//! │  journal (append, fsync)     │← RENEW ────│   chunk (any engine) │
//! │  RunLock (exclusive)         │← COMPLETE ─│ partial as bit       │
//! │  compose → DONE              │─ OK ──────→│   pattern            │
//! └──────────────────────────────┘            └──────────────────────┘
//! ```
//!
//! * [`LeaseTable`] — server side: grants block-aligned chunk leases
//!   with a TTL, journals remote completions through the job's ordinary
//!   journal, expires and reassigns the leases of dead workers, and
//!   composes the DONE record when the last chunk lands.
//! * [`run_worker`] — client side: the `raddet worker --connect` loop.
//!   Claims leases, reconstructs the job's bit-exact matrix from the
//!   grant's embedded spec, computes chunks on the engine the spec
//!   names ([`crate::coordinator::ChunkRunner`] — `cpu-lu`, `prefix`,
//!   or the exact Bareiss paths in checked `i128` or unbounded
//!   `BigInt`), renews held leases from a heartbeat thread, and
//!   streams partials back in each scalar's canonical encoding.
//!
//! Because chunk partials are deterministic and composition is the
//! fixed-order fold of [`crate::jobs::compose_partials`], a determinant
//! computed by any number of workers — through any interleaving of
//! crashes, lease expiries, and reassignments — is bitwise-identical
//! to a single-process run. `rust/tests/fleet_e2e.rs` proves this with
//! a three-worker fleet and a mid-chunk worker kill; the wire grammar
//! is specified normatively in `docs/PROTOCOL.md`.

pub mod lease_table;
pub mod worker;

pub use lease_table::{
    CalibState, CompleteOutcome, FleetConfig, Grant, GrantOutcome, JobTelemetry, LeaseTable,
    WorkerRow,
};
pub use worker::{run_worker, run_worker_with, Worker, WorkerConfig, WorkerEvent, WorkerReport};
