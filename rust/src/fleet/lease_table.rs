//! Server-side lease table — the authority over which worker owns which
//! chunk of which open fleet job.
//!
//! One table serves one [`JobStore`]. A job is *opened* for fleet
//! execution either by a `JOB SUBMIT fleet …` request or lazily by a
//! later `LEASE GRANT` (which is how a fleet sweep survives a server
//! restart: the new process re-opens the job from its journal and only
//! the unjournaled chunks are granted again). Fleet membership is
//! remembered on disk — an `<id>.fleet` marker beside the journal, set
//! on open and cleared on finish/close — so even an *unpinned* grant
//! (no job filter) after a restart finds and adopts the interrupted
//! sweep; `JOB CANCEL` clears the marker, which is what keeps a
//! cancelled fleet job from being silently re-adopted. While open, the
//! table holds the job's cross-process [`RunLock`] and its journal open
//! for append — remote completions are journaled through exactly the
//! same records an in-process [`crate::jobs::JobRunner`] writes, so a
//! fleet-computed determinant is bitwise-identical to a single-process
//! run and `JOB STATUS` needs no fleet-specific path.
//!
//! Failure semantics:
//!
//! * **Worker death** — a lease not renewed within the TTL expires
//!   (lazily, at the next grant) and the chunk is granted to another
//!   worker. Chunk partials are deterministic, so a reassignment can
//!   never change the final bits, only the wall-clock.
//! * **Late duplicates** — a `LEASE COMPLETE` for a chunk that another
//!   worker already delivered is rejected without touching the journal;
//!   a re-delivery by the *same* worker (a retry after a dropped reply)
//!   is acknowledged idempotently.
//! * **Server death** — the journal holds every accepted partial
//!   (fsync'd before the completion is acknowledged); the in-memory
//!   lease state is rebuilt empty on restart and outstanding remote
//!   work is simply re-granted.
//!
//! Known scaling tradeoff: one table-wide mutex serializes all `LEASE`
//! traffic, including the journal fsync inside [`LeaseTable::complete`]
//! and the journal replay inside a lazy open. At the current scale
//! (chunks of ~10³–10⁶ terms, completions per job every hundreds of
//! milliseconds at best) the lock is never the bottleneck; if fleets
//! grow to many hot jobs, the evolution path is a per-open-job lock
//! with the table map only guarding membership — keep lease TTLs well
//! above worst-case fsync latency until then.

use crate::clock::{self, Clock};
use crate::combin::Chunk;
use crate::jobs::{
    compose_partials, valid_id, ChunkRecord, JobEngine, JobPayload, JobSpec, JobStore, Journal,
    LoadedJob, Record, RunLock,
};
use crate::{Error, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Fleet knobs (server side).
#[derive(Clone, Copy, Debug)]
pub struct FleetConfig {
    /// How long a granted lease stays valid without renewal.
    pub lease_ttl: Duration,
    /// Chunk count for `JOB SUBMIT fleet` specs. Deliberately equal to
    /// the `raddet job submit` default — chunk geometry fixes the f64
    /// composition grouping, so equal defaults keep a default fleet
    /// run bit-comparable to a default local run of the same matrix.
    pub default_chunks: usize,
    /// Lane batch size for fleet-submitted specs (float `cpu` engine).
    pub default_batch: usize,
    /// Cap on simultaneously open fleet jobs (each pins a run lock and
    /// an open journal).
    pub max_open: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            lease_ttl: Duration::from_secs(30),
            default_chunks: 32,
            default_batch: 256,
            max_open: 8,
        }
    }
}

/// One open fleet job: plan + journal + lease bookkeeping.
struct OpenJob {
    spec: JobSpec,
    plan: Vec<Chunk>,
    total_terms: u128,
    journal: Journal,
    _lock: RunLock,
    completed: BTreeMap<u64, ChunkRecord>,
    /// chunk → (worker, lease deadline on the table's [`Clock`]).
    leases: HashMap<u64, (String, Duration)>,
    /// chunk → worker whose partial was journaled (idempotent re-acks
    /// for retried `LEASE COMPLETE`s).
    completed_by: HashMap<u64, String>,
}

impl OpenJob {
    /// Drop leases whose deadline has passed; their chunks become
    /// grantable again.
    fn expire_leases(&mut self, now: Duration) {
        self.leases.retain(|_, (_, deadline)| *deadline > now);
    }

    /// Lowest-index chunk that is neither journaled nor actively leased.
    fn next_free_chunk(&self) -> Option<u64> {
        (0..self.plan.len() as u64)
            .find(|i| !self.completed.contains_key(i) && !self.leases.contains_key(i))
    }
}

/// A granted chunk lease, as handed to the protocol layer.
#[derive(Clone, Debug)]
pub struct Grant {
    /// The job id.
    pub job: String,
    /// Chunk index within the job's plan.
    pub chunk_index: u64,
    /// The rank range to evaluate.
    pub chunk: Chunk,
    /// Lease validity; the worker must renew or complete within it.
    pub ttl: Duration,
    /// The job spec, when the caller asked for it (first grant of this
    /// job on a connection).
    pub spec: Option<JobSpec>,
}

/// Outcome of a `LEASE GRANT`.
#[derive(Clone, Debug)]
pub enum GrantOutcome {
    /// A chunk lease.
    Granted(Grant),
    /// No open fleet job has a free chunk right now.
    Idle,
    /// The requested job has finished (its DONE record is journaled).
    Complete,
}

/// What a `LEASE COMPLETE` achieved.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompleteOutcome {
    /// The partial was journaled; `finished` marks the job's last chunk
    /// (DONE composed and journaled, job closed).
    Accepted {
        /// Chunks journaled after this completion.
        chunks_done: u64,
        /// Chunks in the plan.
        chunks_total: u64,
        /// The job is now complete.
        finished: bool,
    },
    /// Idempotent re-delivery by the worker that already completed the
    /// chunk: acknowledged, nothing journaled.
    Duplicate {
        /// Chunks journaled.
        chunks_done: u64,
        /// Chunks in the plan.
        chunks_total: u64,
    },
}

/// Scan the open-job map for the lowest grantable chunk (lowest job id
/// first), honouring `filter`, and lease it to `worker`.
fn grant_from<F: Fn(&str) -> bool>(
    jobs: &mut BTreeMap<String, OpenJob>,
    worker: &str,
    filter: Option<&str>,
    want_spec: &F,
    now: Duration,
    ttl: Duration,
) -> Option<Grant> {
    for (id, oj) in jobs.iter_mut() {
        if filter.is_some_and(|f| f != id.as_str()) {
            continue;
        }
        oj.expire_leases(now);
        if let Some(idx) = oj.next_free_chunk() {
            oj.leases.insert(idx, (worker.to_string(), now.saturating_add(ttl)));
            let spec = want_spec(id).then(|| oj.spec.clone());
            return Some(Grant {
                job: id.clone(),
                chunk_index: idx,
                chunk: oj.plan[idx as usize],
                ttl,
                spec,
            });
        }
    }
    None
}

/// The lease authority over one [`JobStore`].
pub struct LeaseTable {
    store: JobStore,
    cfg: FleetConfig,
    clock: Arc<dyn Clock>,
    jobs: Mutex<BTreeMap<String, OpenJob>>,
}

impl LeaseTable {
    /// New table over `store` on the production wall clock.
    pub fn new(store: JobStore, cfg: FleetConfig) -> Self {
        Self::with_clock(store, cfg, clock::wall())
    }

    /// New table over `store` reading TTL deadlines from `clock` — the
    /// deterministic-simulation constructor (a
    /// [`crate::clock::SimClock`] makes lease expiry a pure function of
    /// explicit `advance` calls).
    pub fn with_clock(store: JobStore, cfg: FleetConfig, clock: Arc<dyn Clock>) -> Self {
        Self { store, cfg, clock, jobs: Mutex::new(BTreeMap::new()) }
    }

    /// The underlying store.
    pub fn store(&self) -> &JobStore {
        &self.store
    }

    /// The configured lease TTL.
    pub fn lease_ttl(&self) -> Duration {
        self.cfg.lease_ttl
    }

    /// Ids of currently open fleet jobs (sorted).
    pub fn open_jobs(&self) -> Vec<String> {
        self.lock_jobs().keys().cloned().collect()
    }

    fn lock_jobs(&self) -> std::sync::MutexGuard<'_, BTreeMap<String, OpenJob>> {
        self.jobs.lock().expect("lease table poisoned")
    }

    /// Create a durable job and open it for fleet leasing. No chunk
    /// runs until a worker claims it.
    pub fn submit(&self, payload: JobPayload, engine: JobEngine) -> Result<String> {
        let spec = JobSpec {
            payload,
            engine,
            chunks: self.cfg.default_chunks,
            batch: self.cfg.default_batch,
        };
        {
            // Fast-fail on capacity before writing a matrix-sized journal.
            let jobs = self.lock_jobs();
            if jobs.len() >= self.cfg.max_open {
                return Err(Error::Job(format!(
                    "too many open fleet jobs ({}); wait for one to finish",
                    jobs.len()
                )));
            }
        }
        let id = self.store.create(&spec)?;
        let mut jobs = self.lock_jobs();
        match self.open_entry(&mut jobs, &id) {
            Ok(_) => Ok(id),
            Err(e) => {
                // Lost a capacity/lock race after creating: the id never
                // reached the caller, so remove the orphan journal.
                if let Ok(path) = self.store.journal_path(&id) {
                    let _ = self.store.fs().remove_file(&path);
                }
                Err(e)
            }
        }
    }

    /// Open (register) job `id` for fleet leasing. Idempotent for
    /// already-open jobs; `Ok(false)` when the job is already complete.
    pub fn open(&self, id: &str) -> Result<bool> {
        let mut jobs = self.lock_jobs();
        self.open_entry(&mut jobs, id)
    }

    /// Open `id` into `jobs`; `Ok(false)` ⇒ already complete (nothing
    /// inserted). A journal whose chunks are all present but whose DONE
    /// record was lost to a crash is finished here on the spot.
    fn open_entry(
        &self,
        jobs: &mut BTreeMap<String, OpenJob>,
        id: &str,
    ) -> Result<bool> {
        if jobs.contains_key(id) {
            return Ok(true);
        }
        if !self.store.exists(id) {
            return Err(Error::Job(format!("unknown job id {id:?}")));
        }
        if jobs.len() >= self.cfg.max_open {
            return Err(Error::Job(format!(
                "too many open fleet jobs ({}); wait for one to finish",
                jobs.len()
            )));
        }
        let lock = self.store.lock_job(id)?;
        let (mut journal, records) = self.store.open_append(id)?;
        let job = LoadedJob::from_records(id, records)?;
        if job.done.is_some() {
            self.clear_fleet_marker(id);
            return Ok(false); // lock + journal drop here
        }
        if job.completed.len() == job.plan.len() {
            // All partials journaled but the DONE record was torn away:
            // compose and finish without granting anything.
            let (value, terms) = compose_partials(job.plan.len(), &job.completed)?;
            if terms != job.total_terms {
                return Err(Error::Job(format!(
                    "job {id}: journaled {terms} terms, expected {}",
                    job.total_terms
                )));
            }
            journal.append(&Record::Done { terms, value })?;
            self.clear_fleet_marker(id);
            return Ok(false);
        }
        jobs.insert(
            id.to_string(),
            OpenJob {
                spec: job.spec,
                plan: job.plan,
                total_terms: job.total_terms,
                journal,
                _lock: lock,
                completed: job.completed,
                leases: HashMap::new(),
                completed_by: HashMap::new(),
            },
        );
        self.set_fleet_marker(id);
        Ok(true)
    }

    /// Persist fleet membership beside the journal (`<id>.fleet`) so an
    /// unpinned grant in a future server process can find the sweep.
    /// Best-effort: a lost marker only costs restart adoption, never
    /// correctness (the journal stays the single source of truth).
    fn set_fleet_marker(&self, id: &str) {
        let _ = self
            .store
            .fs()
            .write(&self.store.root().join(format!("{id}.fleet")), b"fleet\n");
    }

    fn clear_fleet_marker(&self, id: &str) {
        let _ = self
            .store
            .fs()
            .remove_file(&self.store.root().join(format!("{id}.fleet")));
    }

    /// Ids carrying a fleet marker (sorted) — candidates for lazy
    /// adoption by an unpinned grant.
    fn fleet_markers(&self) -> Vec<String> {
        let mut ids = Vec::new();
        if let Ok(names) = self.store.fs().read_dir_names(self.store.root()) {
            for name in names {
                if let Some(id) = name.strip_suffix(".fleet") {
                    if valid_id(id) {
                        ids.push(id.to_string());
                    }
                }
            }
        }
        ids.sort();
        ids
    }

    /// Claim a chunk lease for `worker`. `filter` restricts the claim
    /// to one job (opening it lazily if needed); without a filter, open
    /// jobs are tried first and then any on-disk fleet marker is
    /// adopted (the server-restart path for unpinned workers).
    /// `want_spec` decides — per granted job id — whether the grant
    /// carries the spec (the server passes its per-connection sent-spec
    /// cache).
    pub fn grant(
        &self,
        worker: &str,
        filter: Option<&str>,
        want_spec: impl Fn(&str) -> bool,
    ) -> Result<GrantOutcome> {
        let mut jobs = self.lock_jobs();
        if let Some(id) = filter {
            if !jobs.contains_key(id) && !self.open_entry(&mut jobs, id)? {
                return Ok(GrantOutcome::Complete);
            }
        }
        let now = self.clock.now();
        if let Some(g) = grant_from(&mut jobs, worker, filter, &want_spec, now, self.cfg.lease_ttl)
        {
            return Ok(GrantOutcome::Granted(g));
        }
        if filter.is_none() {
            // Nothing leasable in memory: adopt fleet-marked jobs from
            // disk (interrupted sweeps from a previous server process).
            // Open errors are soft here — a job locked by another
            // runner or mid-release just isn't adoptable *yet*; an
            // orphan marker (journal gone) is cleaned up.
            let mut adopted = false;
            for id in self.fleet_markers() {
                if jobs.contains_key(&id) {
                    continue;
                }
                match self.open_entry(&mut jobs, &id) {
                    Ok(true) => adopted = true,
                    Ok(false) => {}
                    Err(_) => {
                        if !self.store.exists(&id) {
                            self.clear_fleet_marker(&id);
                        }
                    }
                }
            }
            if adopted {
                if let Some(g) =
                    grant_from(&mut jobs, worker, None, &want_spec, now, self.cfg.lease_ttl)
                {
                    return Ok(GrantOutcome::Granted(g));
                }
            }
        }
        Ok(GrantOutcome::Idle)
    }

    /// Extend `worker`'s lease on a chunk by one TTL window. An expired
    /// lease can be revived here as long as the chunk has not been
    /// swept and re-granted (expiry is lazy, at grant time).
    pub fn renew(&self, worker: &str, id: &str, chunk: u64) -> Result<Duration> {
        let mut jobs = self.lock_jobs();
        let oj = jobs
            .get_mut(id)
            .ok_or_else(|| Error::Job(format!("job {id:?} is not open for fleet leasing")))?;
        match oj.leases.get_mut(&chunk) {
            Some((w, deadline)) if w.as_str() == worker => {
                *deadline = self.clock.deadline(self.cfg.lease_ttl);
                Ok(self.cfg.lease_ttl)
            }
            _ => Err(Error::Job(format!(
                "lease lost: worker {worker:?} does not hold chunk {chunk} of job {id:?}"
            ))),
        }
    }

    /// Deliver a chunk partial. Accepted partials are journaled (fsync'd)
    /// before this returns; the final chunk composes the DONE record and
    /// closes the job, releasing its run lock.
    pub fn complete(
        &self,
        worker: &str,
        id: &str,
        chunk: u64,
        rec: ChunkRecord,
    ) -> Result<CompleteOutcome> {
        let mut jobs = self.lock_jobs();
        let Some(oj) = jobs.get_mut(id) else {
            // The common benign case of a missing entry: the worker's
            // COMPLETE ack was lost and the retry arrived after the
            // final chunk closed the job. The journal decides — a
            // complete job with this chunk in plan gets an idempotent
            // re-ack (nothing journaled either way), anything else is
            // the ordinary not-open error.
            drop(jobs);
            if let Ok(st) = self.store.status(id) {
                if st.complete && (chunk as usize) < st.chunks_total {
                    return Ok(CompleteOutcome::Duplicate {
                        chunks_done: st.chunks_done as u64,
                        chunks_total: st.chunks_total as u64,
                    });
                }
            }
            return Err(Error::Job(format!("job {id:?} is not open for fleet leasing")));
        };
        let total = oj.plan.len() as u64;
        if chunk >= total {
            return Err(Error::Job(format!(
                "chunk index {chunk} outside plan of {total} for job {id:?}"
            )));
        }
        if oj.completed.contains_key(&chunk) {
            let done = oj.completed.len() as u64;
            return match oj.completed_by.get(&chunk) {
                Some(w) if w == worker => {
                    Ok(CompleteOutcome::Duplicate { chunks_done: done, chunks_total: total })
                }
                Some(_) => Err(Error::Job(format!(
                    "lease lost: chunk {chunk} of job {id:?} was completed by another worker"
                ))),
                // Journaled before this open of the job (completer
                // identity is not persisted): treat a re-delivery as
                // the idempotent retry the protocol promises — nothing
                // is journaled either way.
                None => Ok(CompleteOutcome::Duplicate { chunks_done: done, chunks_total: total }),
            };
        }
        if oj.leases.get(&chunk).is_some_and(|(w, _)| w != worker) {
            return Err(Error::Job(format!(
                "lease lost: chunk {chunk} of job {id:?} is leased to another worker"
            )));
        }
        // A holder whose lease expired but whose chunk was never
        // re-granted still lands here: the partial is deterministic, so
        // accepting it loses nothing and saves a recompute.
        if rec.terms as u128 != oj.plan[chunk as usize].len {
            return Err(Error::Job(format!(
                "chunk {chunk} of job {id:?}: {} terms delivered, plan says {}",
                rec.terms, oj.plan[chunk as usize].len
            )));
        }
        // Scalar kinds must match exactly: an `i128:` partial delivered
        // to a `big` job (or any other mix) is a protocol violation,
        // not something to coerce — composition rules differ per
        // scalar, so a mixed journal could change the result.
        if rec.value.scalar_kind() != oj.spec.payload.scalar_kind() {
            return Err(Error::Job(format!(
                "chunk {chunk} of job {id:?}: {} value does not match the job's {} scalar",
                rec.value.scalar_kind(),
                oj.spec.payload.scalar_kind()
            )));
        }
        oj.journal.append(&Record::Chunk { index: chunk, rec: rec.clone() })?;
        oj.completed.insert(chunk, rec);
        oj.completed_by.insert(chunk, worker.to_string());
        oj.leases.remove(&chunk);
        let done = oj.completed.len() as u64;
        let finished = done == total;
        if finished {
            let (value, terms) = compose_partials(oj.plan.len(), &oj.completed)?;
            if terms != oj.total_terms {
                return Err(Error::Job(format!(
                    "job {id}: journaled {terms} terms, expected {}",
                    oj.total_terms
                )));
            }
            oj.journal.append(&Record::Done { terms, value })?;
            jobs.remove(id); // drops the journal and releases the run lock
            self.clear_fleet_marker(id);
        }
        Ok(CompleteOutcome::Accepted { chunks_done: done, chunks_total: total, finished })
    }

    /// Give `worker`'s lease on a chunk back to the free pool.
    pub fn abandon(&self, worker: &str, id: &str, chunk: u64) -> Result<()> {
        let mut jobs = self.lock_jobs();
        let oj = jobs
            .get_mut(id)
            .ok_or_else(|| Error::Job(format!("job {id:?} is not open for fleet leasing")))?;
        match oj.leases.get(&chunk) {
            Some((w, _)) if w == worker => {
                oj.leases.remove(&chunk);
                Ok(())
            }
            _ => Err(Error::Job(format!(
                "lease lost: worker {worker:?} does not hold chunk {chunk} of job {id:?}"
            ))),
        }
    }

    /// Close an open fleet job (cooperative pause): stop granting,
    /// clear its fleet marker (so unpinned grants won't silently
    /// re-adopt a cancelled job), release its run lock. Journaled
    /// chunks survive — a job-pinned `LEASE GRANT`, `JOB RESUME`, or
    /// `raddet job resume` picks the sweep up from the journal.
    /// Returns whether the job was open.
    pub fn close(&self, id: &str) -> bool {
        let closed = self.lock_jobs().remove(id).is_some();
        if closed {
            self.clear_fleet_marker(id);
        }
        closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::SimClock;
    use crate::jobs::{JobRunner, JobValue, RunnerConfig};
    use crate::matrix::gen;
    use crate::testkit::TestRng;

    /// Table over a virtual clock: expiry tests advance time instead of
    /// sleeping, so they are instant and can never race the wall clock.
    fn tmp_table(tag: &str, ttl: Duration) -> (Arc<SimClock>, LeaseTable) {
        let store =
            JobStore::open(crate::testkit::scratch_dir(&format!("fleet-{tag}"))).unwrap();
        let clock = SimClock::new();
        let table = LeaseTable::with_clock(
            store,
            FleetConfig { lease_ttl: ttl, default_chunks: 6, ..Default::default() },
            clock.clone(),
        );
        (clock, table)
    }

    fn submit_f64(table: &LeaseTable, seed: u64) -> String {
        let a = gen::uniform(&mut TestRng::from_seed(seed), 3, 9, -1.0, 1.0);
        table.submit(JobPayload::F64(a), JobEngine::Prefix).unwrap()
    }

    /// Compute a granted chunk the way a worker would.
    fn compute(spec: &JobSpec, chunk: Chunk) -> ChunkRecord {
        let (m, n) = spec.shape();
        let table = crate::combin::PascalTable::new(n as u64, m as u64).unwrap();
        let mut runner = spec.runner();
        let (partial, wm) = runner.run_chunk(spec.payload.as_lease(), &table, chunk).unwrap();
        ChunkRecord { value: partial.into(), terms: wm.terms, micros: 1 }
    }

    #[test]
    fn grant_complete_drains_to_done_matching_inprocess_bits() {
        let (_clock, table) = tmp_table("drain", Duration::from_secs(10));
        let id = submit_f64(&table, 61);
        // Reference: the identical spec run by the in-process runner.
        let spec = {
            let g = match table.grant("w0", Some(id.as_str()), |_| true).unwrap() {
                GrantOutcome::Granted(g) => g,
                other => panic!("{other:?}"),
            };
            let spec = g.spec.clone().unwrap();
            table.abandon("w0", &id, g.chunk_index).unwrap();
            spec
        };
        let ref_store =
            JobStore::open(crate::testkit::scratch_dir("fleet-drain-ref")).unwrap();
        let ref_id = ref_store.create(&spec).unwrap();
        let ref_out = JobRunner::new(RunnerConfig::default())
            .run(&ref_store, &ref_id)
            .unwrap();
        let want = ref_out.status.value.unwrap();

        // Drain all chunks through grant/complete.
        let mut finished = false;
        while !finished {
            let g = match table.grant("w1", Some(id.as_str()), |_| true).unwrap() {
                GrantOutcome::Granted(g) => g,
                other => panic!("{other:?}"),
            };
            let rec = compute(g.spec.as_ref().unwrap_or(&spec), g.chunk);
            match table.complete("w1", &id, g.chunk_index, rec).unwrap() {
                CompleteOutcome::Accepted { finished: f, .. } => finished = f,
                other => panic!("{other:?}"),
            }
        }
        assert!(matches!(
            table.grant("w1", Some(id.as_str()), |_| true).unwrap(),
            GrantOutcome::Complete
        ));
        let st = table.store().status(&id).unwrap();
        assert!(st.complete);
        match (st.value.unwrap(), want) {
            (JobValue::F64(a), JobValue::F64(b)) => assert_eq!(a.to_bits(), b.to_bits()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn big_job_drains_to_the_inprocess_value() {
        use crate::scalar::BigInt;
        let (_clock, table) = tmp_table("big-drain", Duration::from_secs(10));
        // Entries large enough that only the big scalar can finish.
        let a = gen::integer(
            &mut TestRng::from_seed(68),
            6,
            8,
            -900_000_000,
            900_000_000,
        );
        let want: BigInt = crate::linalg::radic_det_generic(&a).unwrap();
        let id = table.submit(JobPayload::Big(a), JobEngine::Prefix).unwrap();
        let mut spec: Option<JobSpec> = None;
        loop {
            let g = match table.grant("w1", Some(id.as_str()), |_| spec.is_none()).unwrap() {
                GrantOutcome::Granted(g) => g,
                GrantOutcome::Complete => break,
                other => panic!("{other:?}"),
            };
            if let Some(s) = g.spec {
                spec = Some(s);
            }
            let rec = compute(spec.as_ref().unwrap(), g.chunk);
            assert!(matches!(&rec.value, JobValue::Big(_)), "{rec:?}");
            table.complete("w1", &id, g.chunk_index, rec).unwrap();
        }
        match table.store().status(&id).unwrap().value.unwrap() {
            JobValue::Big(v) => {
                assert_eq!(v, want);
                assert_eq!(v.to_i128(), None, "the sweep genuinely needed big");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn expired_lease_is_regranted_and_late_complete_rejected() {
        let (clock, table) = tmp_table("expiry", Duration::from_millis(20));
        let id = submit_f64(&table, 62);
        let ga = match table.grant("wa", Some(id.as_str()), |_| true).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        let spec = ga.spec.clone().unwrap();
        // wa stops renewing; past the TTL the same chunk goes to wb.
        clock.advance(Duration::from_millis(60));
        let gb = match table.grant("wb", Some(id.as_str()), |_| false).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        assert_eq!(gb.chunk_index, ga.chunk_index, "expired chunk reassigned first");
        let rec = compute(&spec, gb.chunk);
        assert!(matches!(
            table.complete("wb", &id, gb.chunk_index, rec.clone()).unwrap(),
            CompleteOutcome::Accepted { .. }
        ));
        // wa's late duplicate is rejected and journals nothing…
        let before = table.store().status(&id).unwrap().chunks_done;
        let err = table
            .complete("wa", &id, ga.chunk_index, rec.clone())
            .unwrap_err();
        assert!(err.to_string().contains("lease lost"), "{err}");
        assert_eq!(table.store().status(&id).unwrap().chunks_done, before);
        // …while wb's retry is acknowledged idempotently.
        assert!(matches!(
            table.complete("wb", &id, gb.chunk_index, rec).unwrap(),
            CompleteOutcome::Duplicate { .. }
        ));
        assert_eq!(table.store().status(&id).unwrap().chunks_done, before);
    }

    #[test]
    fn renewal_keeps_a_lease_alive() {
        let (clock, table) = tmp_table("renew", Duration::from_millis(200));
        let id = submit_f64(&table, 63);
        let g = match table.grant("wa", Some(id.as_str()), |_| true).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        for _ in 0..3 {
            clock.advance(Duration::from_millis(60));
            table.renew("wa", &id, g.chunk_index).unwrap();
        }
        // t = 180 ms with the last renewal reaching to 380 ms: advance
        // well past the *original* 200 ms TTL — the chunk is still
        // wa's, so a rival grant gets a different chunk.
        clock.advance(Duration::from_millis(120));
        let gb = match table.grant("wb", Some(id.as_str()), |_| false).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        assert_ne!(gb.chunk_index, g.chunk_index);
        // A stranger cannot renew or abandon wa's lease.
        assert!(table.renew("wb", &id, g.chunk_index).is_err());
        assert!(table.abandon("wb", &id, g.chunk_index).is_err());
    }

    #[test]
    fn complete_validates_terms_and_kind() {
        let (_clock, table) = tmp_table("validate", Duration::from_secs(10));
        let id = submit_f64(&table, 64);
        let g = match table.grant("wa", Some(id.as_str()), |_| true).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        let good = compute(g.spec.as_ref().unwrap(), g.chunk);
        // Wrong term count.
        let bad_terms = ChunkRecord { terms: good.terms + 1, ..good.clone() };
        assert!(table.complete("wa", &id, g.chunk_index, bad_terms).is_err());
        // Wrong value scalar for an f64 job — either exact kind.
        for wrong in [
            JobValue::Exact(1),
            JobValue::Big(crate::scalar::BigInt::from_i64(1)),
        ] {
            let bad_kind = ChunkRecord { value: wrong, ..good.clone() };
            let err = table.complete("wa", &id, g.chunk_index, bad_kind).unwrap_err();
            assert!(err.to_string().contains("scalar"), "{err}");
        }
        // Out-of-plan index.
        assert!(table.complete("wa", &id, 10_000, good.clone()).is_err());
        // The lease survives the rejections and the real record lands.
        assert!(matches!(
            table.complete("wa", &id, g.chunk_index, good).unwrap(),
            CompleteOutcome::Accepted { .. }
        ));
    }

    #[test]
    fn unknown_and_closed_jobs_are_errors() {
        let (_clock, table) = tmp_table("unknown", Duration::from_secs(10));
        assert!(table.grant("wa", Some("job-nope"), |_| true).is_err());
        assert!(table.renew("wa", "job-nope", 0).is_err());
        let id = submit_f64(&table, 65);
        assert!(table.close(&id));
        assert!(!table.close(&id), "close is not idempotent-true");
        // Closed ⇒ leasing verbs on it fail until re-opened…
        assert!(table.renew("wa", &id, 0).is_err());
        // …and a grant lazily re-opens it.
        assert!(matches!(
            table.grant("wa", Some(id.as_str()), |_| true).unwrap(),
            GrantOutcome::Granted(_)
        ));
    }

    #[test]
    fn unpinned_grant_adopts_fleet_jobs_after_restart_and_respects_cancel() {
        let dir = crate::testkit::scratch_dir("fleet-marker");
        let store = JobStore::open(&dir).unwrap();
        let cfg = FleetConfig {
            lease_ttl: Duration::from_secs(10),
            default_chunks: 6,
            ..Default::default()
        };
        let t1 = LeaseTable::new(store.clone(), cfg);
        let a = gen::uniform(&mut TestRng::from_seed(67), 3, 9, -1.0, 1.0);
        let id = t1.submit(JobPayload::F64(a), JobEngine::Prefix).unwrap();
        // "Server restart": a fresh table over the same store, empty
        // in-memory state; the old process's lock must be gone first.
        drop(t1);
        let t2 = LeaseTable::new(store.clone(), cfg);
        match t2.grant("wx", None, |_| true).unwrap() {
            GrantOutcome::Granted(g) => {
                assert_eq!(g.job, id, "marker-led adoption of the interrupted sweep");
                assert!(g.spec.is_some());
            }
            other => panic!("{other:?}"),
        }
        // Cancel clears the marker: yet another "restarted server"
        // sees nothing to adopt without naming the job.
        assert!(t2.close(&id));
        let t3 = LeaseTable::new(store, cfg);
        assert!(matches!(
            t3.grant("wy", None, |_| true).unwrap(),
            GrantOutcome::Idle
        ));
        // Naming it still re-opens (explicit resumption).
        assert!(matches!(
            t3.grant("wy", Some(id.as_str()), |_| true).unwrap(),
            GrantOutcome::Granted(_)
        ));
    }

    #[test]
    fn close_releases_the_run_lock_for_inprocess_resume() {
        let (_clock, table) = tmp_table("close-lock", Duration::from_secs(10));
        let id = submit_f64(&table, 66);
        // While open, the run lock blocks an in-process runner.
        assert!(table.store().lock_job(&id).is_err());
        assert!(table.close(&id));
        let out = JobRunner::new(RunnerConfig::default())
            .run(table.store(), &id)
            .unwrap();
        assert!(out.status.complete);
        // A grant on the finished job reports Complete.
        assert!(matches!(
            table.grant("wa", Some(id.as_str()), |_| true).unwrap(),
            GrantOutcome::Complete
        ));
    }
}
