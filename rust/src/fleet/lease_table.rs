//! Server-side lease table — the authority over which worker owns which
//! chunk of which open fleet job.
//!
//! One table serves one [`JobStore`]. A job is *opened* for fleet
//! execution either by a `JOB SUBMIT fleet …` request or lazily by a
//! later `LEASE GRANT` (which is how a fleet sweep survives a server
//! restart: the new process re-opens the job from its journal and only
//! the unjournaled chunks are granted again). Fleet membership is
//! remembered on disk — an `<id>.fleet` marker beside the journal, set
//! on open and cleared on finish/close — so even an *unpinned* grant
//! (no job filter) after a restart finds and adopts the interrupted
//! sweep; `JOB CANCEL` clears the marker, which is what keeps a
//! cancelled fleet job from being silently re-adopted. While open, the
//! table holds the job's cross-process [`RunLock`] and its journal open
//! for append — remote completions are journaled through exactly the
//! same records an in-process [`crate::jobs::JobRunner`] writes, so a
//! fleet-computed determinant is bitwise-identical to a single-process
//! run and `JOB STATUS` needs no fleet-specific path.
//!
//! Failure semantics:
//!
//! * **Worker death** — a lease not renewed within the TTL expires
//!   (lazily, at the next grant) and the chunk is granted to another
//!   worker. Chunk partials are deterministic, so a reassignment can
//!   never change the final bits, only the wall-clock.
//! * **Late duplicates** — a `LEASE COMPLETE` for a chunk that another
//!   worker already delivered is rejected without touching the journal;
//!   a re-delivery by the *same* worker (a retry after a dropped reply)
//!   is acknowledged idempotently.
//! * **Server death** — the journal holds every accepted partial
//!   (fsync'd before the completion is acknowledged); the in-memory
//!   lease state is rebuilt empty on restart and outstanding remote
//!   work is simply re-granted.
//!
//! Known scaling tradeoff: one table-wide mutex serializes all `LEASE`
//! traffic, including the journal fsync inside [`LeaseTable::complete`]
//! and the journal replay inside a lazy open. At the current scale
//! (chunks of ~10³–10⁶ terms, completions per job every hundreds of
//! milliseconds at best) the lock is never the bottleneck; if fleets
//! grow to many hot jobs, the evolution path is a per-open-job lock
//! with the table map only guarding membership — keep lease TTLs well
//! above worst-case fsync latency until then.

use crate::clock::{self, Clock};
use crate::combin::Chunk;
use crate::jobs::{
    compose_partials, plan_dims_geom, valid_id, ChunkRecord, JobEngine, JobPayload, JobSpec,
    JobStore, Journal, LoadedJob, MeteredFs, Record, RunLock, GEOM_MAX_CHUNKS,
};
use crate::telemetry::{Counter, Event, EventLog, Registry};
use crate::{Error, Result};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// How many finished/closed jobs keep their telemetry in memory.
/// `METRICS JOB` on anything older falls back to the journal-derived
/// status (state + chunk counts, no per-worker rows).
const RECENT_TELEMETRY_CAP: usize = 16;

/// How many calibration / re-lease lifecycle events the table's
/// [`EventLog`] ring retains.
const FLEET_EVENT_CAP: usize = 128;

/// Fleet knobs (server side).
#[derive(Clone, Copy, Debug)]
pub struct FleetConfig {
    /// How long a granted lease stays valid without renewal.
    pub lease_ttl: Duration,
    /// Chunk count for `JOB SUBMIT fleet` specs. Deliberately equal to
    /// the `raddet job submit` default — chunk geometry fixes the f64
    /// composition grouping, so equal defaults keep a default fleet
    /// run bit-comparable to a default local run of the same matrix.
    pub default_chunks: usize,
    /// Lane batch size for fleet-submitted specs (float `cpu` engine).
    pub default_batch: usize,
    /// Cap on simultaneously open fleet jobs (each pins a run lock and
    /// an open journal).
    pub max_open: usize,
    /// Speculative straggler re-lease factor. `Some(f)` re-leases a
    /// held chunk to an idle worker when the fleet's median throughput
    /// is at least `f×` the holder's EWMA (or the holder has produced
    /// no sample for half a TTL); `None` disables speculation. First
    /// `LEASE COMPLETE` wins — losers are rejected, never journaled.
    pub speculate: Option<u32>,
    /// Calibration prefix length: how many of a job's SPEC-plan chunks
    /// to grant as a measurement pass before re-partitioning the
    /// remainder from the observed terms/sec (journaled as a `GEOM`
    /// record, so resume and replay see the same geometry). `0`
    /// disables calibration.
    pub calib_chunks: usize,
    /// Target wall-clock per re-partitioned remainder chunk, in
    /// milliseconds; the calibration pass sizes chunks so one takes
    /// roughly this long at the measured rate.
    pub calib_target_ms: u64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            lease_ttl: Duration::from_secs(30),
            default_chunks: 32,
            default_batch: 256,
            max_open: 8,
            speculate: None,
            calib_chunks: 0,
            calib_target_ms: 500,
        }
    }
}

/// Per-worker telemetry row within one fleet job, as surfaced by
/// `METRICS JOB` and `raddet job top`. Counters are cumulative for the
/// job; `held` is the live lease count at snapshot time.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WorkerRow {
    /// Leases currently held (0 in snapshots of finished jobs).
    pub held: u64,
    /// Chunks this worker completed (journaled partials).
    pub completed: u64,
    /// Leases this worker gave back via `LEASE ABANDON`.
    pub abandoned: u64,
    /// Leases lost to TTL expiry (the missed-heartbeat count).
    pub expired: u64,
    /// Duplicate `LEASE COMPLETE` re-deliveries acknowledged.
    pub duplicates: u64,
    /// Throughput EWMA in milli-terms/second. Fed by server-measured
    /// grant→complete spans and by worker-reported `LEASE RENEW`
    /// bodies; 0 until the first sample. Under the sim clock the spans
    /// are pure virtual time, so this is replay-deterministic.
    pub ewma_mtps: u64,
}

/// Point-in-time telemetry snapshot of one fleet job.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JobTelemetry {
    /// The job id.
    pub id: String,
    /// `open` (leasing now), `done` (completed), or `closed` (paused /
    /// cancelled with journaled progress).
    pub state: String,
    /// Chunks journaled.
    pub chunks_done: u64,
    /// Chunks in the plan.
    pub chunks_total: u64,
    /// Terms covered by journaled chunks.
    pub terms_done: u128,
    /// Terms in the whole job.
    pub terms_total: u128,
    /// Fleet-wide throughput in milli-terms/second (sum of worker
    /// EWMAs); 0 when no worker has produced a sample yet.
    pub tps_milli: u64,
    /// Naive remaining-terms ÷ throughput estimate in milliseconds;
    /// `None` when the throughput sum is 0.
    pub eta_ms: Option<u64>,
    /// The table's speculative re-lease factor, when enabled.
    pub speculate: Option<u32>,
    /// Where the job stands in the adaptive-chunking lifecycle.
    pub calib: CalibState,
    /// Per-worker rows, sorted by worker name.
    pub workers: Vec<(String, WorkerRow)>,
}

/// Adaptive-chunking lifecycle of one fleet job, as surfaced by
/// `METRICS JOB`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CalibState {
    /// No calibration configured (and no GEOM record journaled).
    Off,
    /// Measuring: `done` of the `want` calibration-prefix chunks are
    /// journaled; grants stay inside the prefix until all land.
    Measuring {
        /// Prefix chunks journaled so far.
        done: u64,
        /// Prefix length being measured.
        want: u64,
    },
    /// Geometry chosen (journaled as a GEOM record): the remainder was
    /// re-partitioned into `chunks` block-aligned chunks.
    Chosen {
        /// Remainder chunk count the calibration pass picked.
        chunks: u64,
    },
}

/// Registry counters for fleet lease traffic (the `fleet_*` family).
#[derive(Clone, Debug)]
struct FleetMetrics {
    grants: Counter,
    renews: Counter,
    completes: Counter,
    duplicates: Counter,
    expiries: Counter,
    abandons: Counter,
    /// Speculative re-leases granted (`fleet_release_grants_total`).
    release_grants: Counter,
    /// Raced chunks won by a first COMPLETE (`fleet_release_wins_total`).
    release_wins: Counter,
    /// Lease entries evicted by a rival's win (`fleet_release_losses_total`).
    release_losses: Counter,
}

impl FleetMetrics {
    fn register(reg: &Registry) -> FleetMetrics {
        FleetMetrics {
            grants: reg.counter("fleet_grants_total"),
            renews: reg.counter("fleet_renews_total"),
            completes: reg.counter("fleet_completes_total"),
            duplicates: reg.counter("fleet_duplicates_total"),
            expiries: reg.counter("fleet_expiries_total"),
            abandons: reg.counter("fleet_abandons_total"),
            release_grants: reg.counter("fleet_release_grants_total"),
            release_wins: reg.counter("fleet_release_wins_total"),
            release_losses: reg.counter("fleet_release_losses_total"),
        }
    }
}

/// Throughput sample in milli-terms/second: `terms` over `micros` of
/// clock time. The 1 µs floor matters under sim, where a zero-latency
/// exchange completes in zero virtual time — such workers saturate
/// high rather than divide by zero, so a deliberately slow peer is
/// always the *lowest* nonzero EWMA.
fn sample_mtps(terms: u64, micros: u64) -> u64 {
    let v = terms as u128 * 1_000_000_000 / micros.max(1) as u128;
    v.min(u64::MAX as u128) as u64
}

/// Quarter-weight EWMA step; the first sample seeds the average.
fn ewma_update(ewma: u64, sample: u64) -> u64 {
    if ewma == 0 {
        sample
    } else {
        ((3 * ewma as u128 + sample as u128) / 4) as u64
    }
}

/// One active lease on a chunk. A chunk normally carries one entry;
/// a speculative re-lease adds a second and the entries race — first
/// `LEASE COMPLETE` wins, the rest are evicted.
#[derive(Clone, Debug)]
struct LeaseEntry {
    worker: String,
    /// Lease deadline on the table's [`Clock`].
    deadline: Duration,
    /// Grant timestamp, for the server-measured grant→complete
    /// throughput span (and the no-sample straggler age test).
    granted: Duration,
    /// Whether this entry was granted as a straggler re-lease.
    speculative: bool,
}

/// One open fleet job: plan + journal + lease bookkeeping.
struct OpenJob {
    spec: JobSpec,
    plan: Vec<Chunk>,
    total_terms: u128,
    journal: Journal,
    _lock: RunLock,
    completed: BTreeMap<u64, ChunkRecord>,
    /// chunk → active lease entries (never empty; the key is removed
    /// with the last entry). More than one entry only while a
    /// speculative re-lease races the original holder.
    leases: HashMap<u64, Vec<LeaseEntry>>,
    /// chunk → worker whose partial was journaled (idempotent re-acks
    /// for retried `LEASE COMPLETE`s).
    completed_by: HashMap<u64, String>,
    /// Journaled GEOM geometry `(calibration prefix, remainder
    /// chunks)`, whether chosen by this table or replayed at open.
    geom: Option<(u64, u64)>,
    /// Active calibration: grants stay below this prefix length until
    /// all prefix chunks are journaled and a GEOM record is chosen.
    calib: Option<u64>,
    /// Per-worker telemetry rows (BTreeMap for sorted snapshots).
    workers: BTreeMap<String, WorkerRow>,
    /// worker → last cumulative `(terms, micros)` it reported in a
    /// `LEASE RENEW` body, so the next report yields a delta sample.
    last_report: HashMap<String, (u64, u64)>,
}

impl OpenJob {
    /// Drop lease entries whose deadline has passed; a chunk with no
    /// surviving entry becomes grantable again. Returns how many
    /// entries expired, after attributing each to the worker that let
    /// it lapse.
    fn expire_leases(&mut self, now: Duration) -> u64 {
        let mut expired = 0u64;
        let workers = &mut self.workers;
        self.leases.retain(|_, entries| {
            entries.retain(|e| {
                if e.deadline <= now {
                    workers.entry(e.worker.clone()).or_default().expired += 1;
                    expired += 1;
                    false
                } else {
                    true
                }
            });
            !entries.is_empty()
        });
        expired
    }

    /// Lowest-index chunk below `bound` that is neither journaled nor
    /// actively leased. `bound` is the calibration prefix while a
    /// measurement pass is running, the plan length otherwise.
    fn next_free_chunk(&self, bound: u64) -> Option<u64> {
        (0..bound.min(self.plan.len() as u64))
            .find(|i| !self.completed.contains_key(i) && !self.leases.contains_key(i))
    }
}

/// A granted chunk lease, as handed to the protocol layer.
#[derive(Clone, Debug)]
pub struct Grant {
    /// The job id.
    pub job: String,
    /// Chunk index within the job's plan.
    pub chunk_index: u64,
    /// The rank range to evaluate.
    pub chunk: Chunk,
    /// Lease validity; the worker must renew or complete within it.
    pub ttl: Duration,
    /// The job spec, when the caller asked for it (first grant of this
    /// job on a connection).
    pub spec: Option<JobSpec>,
}

/// Outcome of a `LEASE GRANT`.
#[derive(Clone, Debug)]
pub enum GrantOutcome {
    /// A chunk lease.
    Granted(Grant),
    /// No open fleet job has a free chunk right now.
    Idle,
    /// The requested job has finished (its DONE record is journaled).
    Complete,
}

/// What a `LEASE COMPLETE` achieved.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompleteOutcome {
    /// The partial was journaled; `finished` marks the job's last chunk
    /// (DONE composed and journaled, job closed).
    Accepted {
        /// Chunks journaled after this completion.
        chunks_done: u64,
        /// Chunks in the plan.
        chunks_total: u64,
        /// The job is now complete.
        finished: bool,
    },
    /// Idempotent re-delivery by the worker that already completed the
    /// chunk: acknowledged, nothing journaled.
    Duplicate {
        /// Chunks journaled.
        chunks_done: u64,
        /// Chunks in the plan.
        chunks_total: u64,
    },
}

/// Median of the positive throughput EWMAs across a job's worker rows
/// (`None` until some worker has produced a sample).
fn median_ewma(workers: &BTreeMap<String, WorkerRow>) -> Option<u64> {
    let mut v: Vec<u64> = workers
        .values()
        .map(|r| r.ewma_mtps)
        .filter(|&e| e > 0)
        .collect();
    v.sort_unstable();
    v.get(v.len() / 2).copied()
}

/// Pick a straggling chunk to re-lease speculatively to `worker`, or
/// `None` if no held chunk qualifies. A chunk qualifies when it has
/// exactly one active lease, held by someone else, whose holder is a
/// straggler — EWMA at least `factor×` below the fleet median, or no
/// sample at all half a TTL after the grant — and `worker` is at least
/// as fast as the holder. Among qualifiers the slowest holder wins,
/// ties broken by lowest chunk index, so the choice is deterministic
/// despite the `HashMap` iteration order.
fn speculative_candidate(
    oj: &OpenJob,
    worker: &str,
    now: Duration,
    ttl: Duration,
    factor: u32,
) -> Option<u64> {
    let median = median_ewma(&oj.workers);
    let requester = oj.workers.get(worker).map_or(0, |r| r.ewma_mtps);
    let mut best: Option<(u64, u64)> = None;
    for (&chunk, entries) in &oj.leases {
        if entries.len() != 1 || oj.completed.contains_key(&chunk) {
            continue;
        }
        let e = &entries[0];
        if e.worker == worker {
            continue;
        }
        let holder = oj.workers.get(&e.worker).map_or(0, |r| r.ewma_mtps);
        let straggling = if holder == 0 {
            now.saturating_sub(e.granted) > ttl / 2
        } else {
            median.is_some_and(|med| med as u128 >= factor as u128 * holder as u128)
        };
        if !straggling || requester < holder {
            continue;
        }
        if best.map_or(true, |b| (holder, chunk) < b) {
            best = Some((holder, chunk));
        }
    }
    best.map(|(_, chunk)| chunk)
}

/// The lease authority over one [`JobStore`].
pub struct LeaseTable {
    store: JobStore,
    cfg: FleetConfig,
    clock: Arc<dyn Clock>,
    jobs: Mutex<BTreeMap<String, OpenJob>>,
    /// `fleet_*` registry counters; `None` until [`Self::with_registry`].
    metrics: Option<FleetMetrics>,
    /// Telemetry of recently finished/closed jobs, oldest first, capped
    /// at [`RECENT_TELEMETRY_CAP`] — `METRICS JOB` keeps answering with
    /// per-worker rows after the final chunk removed the [`OpenJob`].
    recent: Mutex<VecDeque<(String, JobTelemetry)>>,
    /// Calibration / re-lease lifecycle events, stamped on this table's
    /// clock (virtual under sim ⇒ replay-identical streams).
    events: EventLog,
}

impl LeaseTable {
    /// New table over `store` on the production wall clock.
    pub fn new(store: JobStore, cfg: FleetConfig) -> Self {
        Self::with_clock(store, cfg, clock::wall())
    }

    /// New table over `store` reading TTL deadlines from `clock` — the
    /// deterministic-simulation constructor (a
    /// [`crate::clock::SimClock`] makes lease expiry a pure function of
    /// explicit `advance` calls).
    pub fn with_clock(store: JobStore, cfg: FleetConfig, clock: Arc<dyn Clock>) -> Self {
        let events = EventLog::new(Arc::clone(&clock), FLEET_EVENT_CAP);
        Self {
            store,
            cfg,
            clock,
            jobs: Mutex::new(BTreeMap::new()),
            metrics: None,
            recent: Mutex::new(VecDeque::new()),
            events,
        }
    }

    /// Sink `fleet_*` counters into `registry` and re-wrap the store's
    /// filesystem in a [`MeteredFs`] (journal append/fsync latency on
    /// this table's clock). Called by `ServiceCore::new`, which owns
    /// the one registry per service.
    pub fn with_registry(mut self, registry: &Arc<Registry>) -> Self {
        let fs = MeteredFs::new(
            Arc::clone(self.store.fs()),
            Arc::clone(&self.clock),
            registry,
        );
        self.store = self.store.with_fs(fs);
        self.metrics = Some(FleetMetrics::register(registry));
        self
    }

    /// Sink `fleet_*` counters into `registry` without touching the
    /// store's filesystem. For tables rebuilt from a store whose fs is
    /// already metered (e.g. `Server::with_fleet_config` cloning the
    /// manager's store after `ServiceCore::new`): the full
    /// [`Self::with_registry`] there would wrap the fs twice and
    /// double-count every append and fsync.
    pub(crate) fn with_registry_counters(mut self, registry: &Arc<Registry>) -> Self {
        self.metrics = Some(FleetMetrics::register(registry));
        self
    }

    /// The underlying store.
    pub fn store(&self) -> &JobStore {
        &self.store
    }

    /// The configured lease TTL.
    pub fn lease_ttl(&self) -> Duration {
        self.cfg.lease_ttl
    }

    /// The retained calibration / re-lease lifecycle events, oldest
    /// first. Kinds: `calibrate` (GEOM chosen), `calibrate_abandon`
    /// (calibration dropped — a journaled chunk outside the prefix
    /// forecloses a GEOM), `release_grant` (speculative re-lease
    /// granted), `release_win` (a raced chunk's first COMPLETE landed).
    pub fn events(&self) -> Vec<Event> {
        self.events.events()
    }

    /// Ids of currently open fleet jobs (sorted).
    pub fn open_jobs(&self) -> Vec<String> {
        self.lock_jobs().keys().cloned().collect()
    }

    fn lock_jobs(&self) -> std::sync::MutexGuard<'_, BTreeMap<String, OpenJob>> {
        self.jobs.lock().expect("lease table poisoned")
    }

    /// Create a durable job and open it for fleet leasing. No chunk
    /// runs until a worker claims it.
    pub fn submit(&self, payload: JobPayload, engine: JobEngine) -> Result<String> {
        let spec = JobSpec {
            payload,
            engine,
            chunks: self.cfg.default_chunks,
            batch: self.cfg.default_batch,
        };
        {
            // Fast-fail on capacity before writing a matrix-sized journal.
            let jobs = self.lock_jobs();
            if jobs.len() >= self.cfg.max_open {
                return Err(Error::Job(format!(
                    "too many open fleet jobs ({}); wait for one to finish",
                    jobs.len()
                )));
            }
        }
        let id = self.store.create(&spec)?;
        let mut jobs = self.lock_jobs();
        match self.open_entry(&mut jobs, &id) {
            Ok(_) => Ok(id),
            Err(e) => {
                // Lost a capacity/lock race after creating: the id never
                // reached the caller, so remove the orphan journal.
                if let Ok(path) = self.store.journal_path(&id) {
                    let _ = self.store.fs().remove_file(&path);
                }
                Err(e)
            }
        }
    }

    /// Open (register) job `id` for fleet leasing. Idempotent for
    /// already-open jobs; `Ok(false)` when the job is already complete.
    pub fn open(&self, id: &str) -> Result<bool> {
        let mut jobs = self.lock_jobs();
        self.open_entry(&mut jobs, id)
    }

    /// Open `id` into `jobs`; `Ok(false)` ⇒ already complete (nothing
    /// inserted). A journal whose chunks are all present but whose DONE
    /// record was lost to a crash is finished here on the spot.
    fn open_entry(
        &self,
        jobs: &mut BTreeMap<String, OpenJob>,
        id: &str,
    ) -> Result<bool> {
        if jobs.contains_key(id) {
            return Ok(true);
        }
        if !self.store.exists(id) {
            return Err(Error::Job(format!("unknown job id {id:?}")));
        }
        if jobs.len() >= self.cfg.max_open {
            return Err(Error::Job(format!(
                "too many open fleet jobs ({}); wait for one to finish",
                jobs.len()
            )));
        }
        let lock = self.store.lock_job(id)?;
        let (mut journal, records) = self.store.open_append(id)?;
        let job = LoadedJob::from_records(id, records)?;
        if job.done.is_some() {
            self.clear_fleet_marker(id);
            return Ok(false); // lock + journal drop here
        }
        if job.completed.len() == job.plan.len() {
            // All partials journaled but the DONE record was torn away:
            // compose and finish without granting anything.
            let (value, terms) = compose_partials(job.plan.len(), &job.completed)?;
            if terms != job.total_terms {
                return Err(Error::Job(format!(
                    "job {id}: journaled {terms} terms, expected {}",
                    job.total_terms
                )));
            }
            journal.append(&Record::Done { terms, value })?;
            self.clear_fleet_marker(id);
            return Ok(false);
        }
        // Calibration is only meaningful for a job whose geometry is
        // still undecided: no journaled GEOM, a prefix strictly shorter
        // than the plan, and no chunk journaled beyond the prefix (a
        // resumed sweep that already ran past it keeps the SPEC plan —
        // the GEOM structural rule requires every pre-GEOM chunk to sit
        // inside the calibration prefix).
        let calib = if self.cfg.calib_chunks == 0 || job.geom.is_some() {
            None
        } else {
            let want = (self.cfg.calib_chunks as u64).min(job.plan.len() as u64);
            ((want as usize) < job.plan.len() && job.completed.keys().all(|&i| i < want))
                .then_some(want)
        };
        jobs.insert(
            id.to_string(),
            OpenJob {
                spec: job.spec,
                plan: job.plan,
                total_terms: job.total_terms,
                journal,
                _lock: lock,
                completed: job.completed,
                leases: HashMap::new(),
                completed_by: HashMap::new(),
                geom: job.geom,
                calib,
                workers: BTreeMap::new(),
                last_report: HashMap::new(),
            },
        );
        self.set_fleet_marker(id);
        Ok(true)
    }

    /// Persist fleet membership beside the journal (`<id>.fleet`) so an
    /// unpinned grant in a future server process can find the sweep.
    /// Best-effort: a lost marker only costs restart adoption, never
    /// correctness (the journal stays the single source of truth).
    fn set_fleet_marker(&self, id: &str) {
        let _ = self
            .store
            .fs()
            .write(&self.store.root().join(format!("{id}.fleet")), b"fleet\n");
    }

    fn clear_fleet_marker(&self, id: &str) {
        let _ = self
            .store
            .fs()
            .remove_file(&self.store.root().join(format!("{id}.fleet")));
    }

    /// Ids carrying a fleet marker (sorted) — candidates for lazy
    /// adoption by an unpinned grant.
    fn fleet_markers(&self) -> Vec<String> {
        let mut ids = Vec::new();
        if let Ok(names) = self.store.fs().read_dir_names(self.store.root()) {
            for name in names {
                if let Some(id) = name.strip_suffix(".fleet") {
                    if valid_id(id) {
                        ids.push(id.to_string());
                    }
                }
            }
        }
        ids.sort();
        ids
    }

    /// Claim a chunk lease for `worker`. `filter` restricts the claim
    /// to one job (opening it lazily if needed); without a filter, open
    /// jobs are tried first and then any on-disk fleet marker is
    /// adopted (the server-restart path for unpinned workers).
    /// `want_spec` decides — per granted job id — whether the grant
    /// carries the spec (the server passes its per-connection sent-spec
    /// cache).
    pub fn grant(
        &self,
        worker: &str,
        filter: Option<&str>,
        want_spec: impl Fn(&str) -> bool,
    ) -> Result<GrantOutcome> {
        let mut jobs = self.lock_jobs();
        if let Some(id) = filter {
            if !jobs.contains_key(id) && !self.open_entry(&mut jobs, id)? {
                return Ok(GrantOutcome::Complete);
            }
        }
        let now = self.clock.now();
        let mut expired = 0u64;
        let mut granted =
            self.grant_from(&mut jobs, worker, filter, &want_spec, now, &mut expired)?;
        if granted.is_none() && filter.is_none() {
            // Nothing leasable in memory: adopt fleet-marked jobs from
            // disk (interrupted sweeps from a previous server process).
            // Open errors are soft here — a job locked by another
            // runner or mid-release just isn't adoptable *yet*; an
            // orphan marker (journal gone) is cleaned up.
            let mut adopted = false;
            for id in self.fleet_markers() {
                if jobs.contains_key(&id) {
                    continue;
                }
                match self.open_entry(&mut jobs, &id) {
                    Ok(true) => adopted = true,
                    Ok(false) => {}
                    Err(_) => {
                        if !self.store.exists(&id) {
                            self.clear_fleet_marker(&id);
                        }
                    }
                }
            }
            if adopted {
                granted =
                    self.grant_from(&mut jobs, worker, None, &want_spec, now, &mut expired)?;
            }
        }
        if let Some(m) = &self.metrics {
            m.expiries.add(expired);
            if granted.is_some() {
                m.grants.inc();
            }
        }
        Ok(match granted {
            Some(g) => GrantOutcome::Granted(g),
            None => GrantOutcome::Idle,
        })
    }

    /// Scan the open-job map for the lowest grantable chunk (lowest job
    /// id first), honouring `filter`, and lease it to `worker`. When a
    /// job has no free chunk and speculation is configured, a held
    /// straggler chunk may be re-leased instead. Fallible because an
    /// exhausted calibration prefix chooses and journals the GEOM
    /// record here, on the granting path.
    fn grant_from<F: Fn(&str) -> bool>(
        &self,
        jobs: &mut BTreeMap<String, OpenJob>,
        worker: &str,
        filter: Option<&str>,
        want_spec: &F,
        now: Duration,
        expired: &mut u64,
    ) -> Result<Option<Grant>> {
        let ttl = self.cfg.lease_ttl;
        for (id, oj) in jobs.iter_mut() {
            if filter.is_some_and(|f| f != id.as_str()) {
                continue;
            }
            *expired += oj.expire_leases(now);
            self.finish_calibration(id, oj)?;
            let bound = oj.calib.unwrap_or(oj.plan.len() as u64);
            let (idx, speculative) = match oj.next_free_chunk(bound) {
                Some(idx) => (idx, false),
                None => match self
                    .cfg
                    .speculate
                    .and_then(|f| speculative_candidate(oj, worker, now, ttl, f))
                {
                    Some(idx) => (idx, true),
                    None => continue,
                },
            };
            oj.leases.entry(idx).or_default().push(LeaseEntry {
                worker: worker.to_string(),
                deadline: now.saturating_add(ttl),
                granted: now,
                speculative,
            });
            if speculative {
                if let Some(m) = &self.metrics {
                    m.release_grants.inc();
                }
                self.events
                    .record("release_grant", format!("job={id} chunk={idx} to={worker}"));
            }
            let spec = want_spec(id).then(|| oj.spec.clone());
            return Ok(Some(Grant {
                job: id.clone(),
                chunk_index: idx,
                chunk: oj.plan[idx as usize],
                ttl,
                spec,
            }));
        }
        Ok(None)
    }

    /// If `oj`'s calibration prefix is fully journaled, choose the
    /// remainder geometry from the measured rate, journal it as a GEOM
    /// record, and re-partition the plan. The rate comes from the
    /// journaled chunk records (worker-measured terms and micros), not
    /// in-memory state, so a restarted server that replays the journal
    /// *before* choosing would measure the same figures. A failed GEOM
    /// append leaves calibration active — the next grant retries.
    fn finish_calibration(&self, id: &str, oj: &mut OpenJob) -> Result<()> {
        let Some(want) = oj.calib else { return Ok(()) };
        // A chunk journaled past the prefix makes a GEOM append
        // structurally invalid — replay rejects any pre-GEOM chunk
        // outside the calibration prefix, so appending one here would
        // corrupt the journal for every later load. `complete` bounds
        // indices while calibration is active, so this is
        // defence-in-depth (a journal inherited from before that bound
        // existed); abandon calibration and keep the SPEC plan, exactly
        // like the resumed-sweep case in `open_entry`.
        if oj.completed.keys().any(|&i| i >= want) {
            oj.calib = None;
            self.events.record(
                "calibrate_abandon",
                format!("job={id} calib={want} reason=chunk-outside-prefix"),
            );
            return Ok(());
        }
        if !(0..want).all(|i| oj.completed.contains_key(&i)) {
            return Ok(());
        }
        let mut terms: u128 = 0;
        let mut micros: u128 = 0;
        for i in 0..want {
            let rec = &oj.completed[&i];
            terms += rec.terms as u128;
            micros += rec.micros as u128;
        }
        // Terms one remainder chunk should carry to take ~target_ms at
        // the measured rate: terms/µs × target_ms×1000 µs.
        let target_ms = self.cfg.calib_target_ms.max(1) as u128;
        let target_terms = (terms.saturating_mul(1_000).saturating_mul(target_ms)
            / micros.max(1))
        .max(1);
        let prefix_end = oj.plan[want as usize - 1].end();
        let remaining = oj.total_terms.saturating_sub(prefix_end);
        // div_ceil, not `(remaining + target_terms - 1) / target_terms`:
        // target_terms saturates near u128::MAX for huge term counts ×
        // a huge --calib-target-ms, where the naive ceiling's addition
        // would overflow.
        let rechunks = remaining
            .div_ceil(target_terms)
            .clamp(1, GEOM_MAX_CHUNKS as u128) as u64;
        oj.journal.append(&Record::Geom { calib: want, chunks: rechunks })?;
        let (m, n) = oj.spec.shape();
        let (plan, _) = plan_dims_geom(m, n, oj.spec.chunks, Some((want, rechunks)))?;
        oj.plan = plan;
        oj.geom = Some((want, rechunks));
        oj.calib = None;
        self.events
            .record("calibrate", format!("job={id} calib={want} chunks={rechunks}"));
        Ok(())
    }

    /// Extend `worker`'s lease on a chunk by one TTL window. An expired
    /// lease can be revived here as long as the chunk has not been
    /// swept and re-granted (expiry is lazy, at grant time).
    ///
    /// `report` is the worker's cumulative `(terms, micros)` progress
    /// counters, when its `LEASE RENEW` carried them; the table folds
    /// the delta since the previous report into the worker's
    /// throughput EWMA. Cumulative (not per-report) figures make lost
    /// replies harmless — the next report's delta absorbs the gap.
    pub fn renew(
        &self,
        worker: &str,
        id: &str,
        chunk: u64,
        report: Option<(u64, u64)>,
    ) -> Result<Duration> {
        let mut jobs = self.lock_jobs();
        let oj = jobs
            .get_mut(id)
            .ok_or_else(|| Error::Job(format!("job {id:?} is not open for fleet leasing")))?;
        let entry = oj
            .leases
            .get_mut(&chunk)
            .and_then(|entries| entries.iter_mut().find(|e| e.worker == worker));
        match entry {
            Some(e) => {
                e.deadline = self.clock.deadline(self.cfg.lease_ttl);
                if let Some((terms, micros)) = report {
                    let (seen_t, seen_us) =
                        oj.last_report.get(worker).copied().unwrap_or((0, 0));
                    let dt = terms.saturating_sub(seen_t);
                    let dus = micros.saturating_sub(seen_us);
                    oj.last_report.insert(worker.to_string(), (terms, micros));
                    if dt > 0 {
                        let row = oj.workers.entry(worker.to_string()).or_default();
                        row.ewma_mtps = ewma_update(row.ewma_mtps, sample_mtps(dt, dus));
                    }
                }
                if let Some(m) = &self.metrics {
                    m.renews.inc();
                }
                Ok(self.cfg.lease_ttl)
            }
            None => Err(Error::Job(format!(
                "lease lost: worker {worker:?} does not hold chunk {chunk} of job {id:?}"
            ))),
        }
    }

    /// Deliver a chunk partial. Accepted partials are journaled (fsync'd)
    /// before this returns; the final chunk composes the DONE record and
    /// closes the job, releasing its run lock.
    pub fn complete(
        &self,
        worker: &str,
        id: &str,
        chunk: u64,
        rec: ChunkRecord,
    ) -> Result<CompleteOutcome> {
        let mut jobs = self.lock_jobs();
        let Some(oj) = jobs.get_mut(id) else {
            // The common benign case of a missing entry: the worker's
            // COMPLETE ack was lost and the retry arrived after the
            // final chunk closed the job. The journal decides — a
            // complete job with this chunk in plan gets an idempotent
            // re-ack (nothing journaled either way), anything else is
            // the ordinary not-open error.
            drop(jobs);
            if let Ok(st) = self.store.status(id) {
                if st.complete && (chunk as usize) < st.chunks_total {
                    // Attribute the late duplicate in the retained
                    // telemetry of the (now finished) job, if any —
                    // but only to a worker that actually participated.
                    // A sender with no row never held a lease here;
                    // acknowledging its duplicate is enough, inventing
                    // a row would credit participation that never
                    // happened.
                    let mut recent =
                        self.recent.lock().expect("recent telemetry poisoned");
                    if let Some((_, snap)) =
                        recent.iter_mut().find(|(rid, _)| rid == id)
                    {
                        if let Some((_, row)) =
                            snap.workers.iter_mut().find(|(w, _)| w == worker)
                        {
                            row.duplicates += 1;
                        }
                    }
                    if let Some(m) = &self.metrics {
                        m.duplicates.inc();
                    }
                    return Ok(CompleteOutcome::Duplicate {
                        chunks_done: st.chunks_done as u64,
                        chunks_total: st.chunks_total as u64,
                    });
                }
            }
            return Err(Error::Job(format!("job {id:?} is not open for fleet leasing")));
        };
        let total = oj.plan.len() as u64;
        if chunk >= total {
            return Err(Error::Job(format!(
                "chunk index {chunk} outside plan of {total} for job {id:?}"
            )));
        }
        // While calibration is active, grants stay inside the prefix
        // and the remainder geometry is still undecided: journaling a
        // chunk past the bound (a grant from before calibration was
        // enabled, or a fabricated index — per-chunk term counts are
        // derivable from the spec) would put a CHUNK record before the
        // GEOM that structurally forbids it, corrupting the journal for
        // every later load. Reject it; the re-partitioned remainder is
        // recomputed under the chosen geometry anyway.
        if let Some(want) = oj.calib {
            if chunk >= want {
                return Err(Error::Job(format!(
                    "chunk index {chunk} outside the active calibration prefix of {want} for job {id:?}"
                )));
            }
        }
        if oj.completed.contains_key(&chunk) {
            let done = oj.completed.len() as u64;
            match oj.completed_by.get(&chunk) {
                Some(w) if w != worker => {
                    return Err(Error::Job(format!(
                        "lease lost: chunk {chunk} of job {id:?} was completed by another worker"
                    )));
                }
                // Idempotent re-ack: the same worker retrying —
                // nothing is journaled, the retry is attributed.
                Some(_) => {
                    oj.workers.entry(worker.to_string()).or_default().duplicates += 1;
                }
                // A chunk journaled before this open of the job: the
                // completer identity was not persisted, so acknowledge
                // without attributing a duplicate to a worker that may
                // never have held the lease.
                None => {}
            }
            if let Some(m) = &self.metrics {
                m.duplicates.inc();
            }
            return Ok(CompleteOutcome::Duplicate { chunks_done: done, chunks_total: total });
        }
        if oj
            .leases
            .get(&chunk)
            .is_some_and(|entries| !entries.iter().any(|e| e.worker == worker))
        {
            return Err(Error::Job(format!(
                "lease lost: chunk {chunk} of job {id:?} is leased to another worker"
            )));
        }
        // A holder whose lease expired but whose chunk was never
        // re-granted still lands here: the partial is deterministic, so
        // accepting it loses nothing and saves a recompute.
        if rec.terms as u128 != oj.plan[chunk as usize].len {
            return Err(Error::Job(format!(
                "chunk {chunk} of job {id:?}: {} terms delivered, plan says {}",
                rec.terms, oj.plan[chunk as usize].len
            )));
        }
        // Scalar kinds must match exactly: an `i128:` partial delivered
        // to a `big` job (or any other mix) is a protocol violation,
        // not something to coerce — composition rules differ per
        // scalar, so a mixed journal could change the result.
        if rec.value.scalar_kind() != oj.spec.payload.scalar_kind() {
            return Err(Error::Job(format!(
                "chunk {chunk} of job {id:?}: {} value does not match the job's {} scalar",
                rec.value.scalar_kind(),
                oj.spec.payload.scalar_kind()
            )));
        }
        let delivered_terms = rec.terms;
        oj.journal.append(&Record::Chunk { index: chunk, rec: rec.clone() })?;
        oj.completed.insert(chunk, rec);
        oj.completed_by.insert(chunk, worker.to_string());
        // First COMPLETE wins the chunk outright: every other lease
        // entry — the original holder still racing a speculative
        // duplicate, or vice versa — is evicted here. A loser's later
        // delivery hits the completed-by-another-worker rejection
        // above, which is benign (nothing journaled).
        let entries = oj.leases.remove(&chunk).unwrap_or_default();
        let raced = entries.iter().any(|e| e.speculative);
        let losses = entries.iter().filter(|e| e.worker != worker).count() as u64;
        let t0 = entries.iter().find(|e| e.worker == worker).map(|e| e.granted);
        // Grant→complete span on the table's own clock: the
        // sim-deterministic throughput signal (a straggling worker's
        // exchanges advance more virtual time, so its samples are
        // smaller). Absent when the lease expired before delivery —
        // a span across an expiry would misstate throughput.
        let row = oj.workers.entry(worker.to_string()).or_default();
        row.completed += 1;
        if let Some(t0) = t0 {
            let span = self.clock.now().saturating_sub(t0);
            let span_us = span.as_micros().min(u64::MAX as u128) as u64;
            row.ewma_mtps = ewma_update(row.ewma_mtps, sample_mtps(delivered_terms, span_us));
        }
        if raced {
            if let Some(m) = &self.metrics {
                m.release_wins.inc();
                m.release_losses.add(losses);
            }
            self.events.record(
                "release_win",
                format!("job={id} chunk={chunk} winner={worker} evicted={losses}"),
            );
        }
        if let Some(m) = &self.metrics {
            m.completes.inc();
        }
        let done = oj.completed.len() as u64;
        let finished = done == total;
        if finished {
            let (value, terms) = compose_partials(oj.plan.len(), &oj.completed)?;
            if terms != oj.total_terms {
                return Err(Error::Job(format!(
                    "job {id}: journaled {terms} terms, expected {}",
                    oj.total_terms
                )));
            }
            oj.journal.append(&Record::Done { terms, value })?;
            let snap = snapshot_open(id, oj, "done", self.cfg.speculate);
            jobs.remove(id); // drops the journal and releases the run lock
            drop(jobs);
            self.remember(snap);
            self.clear_fleet_marker(id);
        }
        Ok(CompleteOutcome::Accepted { chunks_done: done, chunks_total: total, finished })
    }

    /// Give `worker`'s lease on a chunk back to the free pool.
    pub fn abandon(&self, worker: &str, id: &str, chunk: u64) -> Result<()> {
        let mut jobs = self.lock_jobs();
        let oj = jobs
            .get_mut(id)
            .ok_or_else(|| Error::Job(format!("job {id:?} is not open for fleet leasing")))?;
        let pos = oj
            .leases
            .get(&chunk)
            .and_then(|entries| entries.iter().position(|e| e.worker == worker));
        match pos {
            Some(pos) => {
                let entries = oj.leases.get_mut(&chunk).expect("entry vec vanished");
                entries.remove(pos);
                if entries.is_empty() {
                    oj.leases.remove(&chunk);
                }
                oj.workers.entry(worker.to_string()).or_default().abandoned += 1;
                if let Some(m) = &self.metrics {
                    m.abandons.inc();
                }
                Ok(())
            }
            None => Err(Error::Job(format!(
                "lease lost: worker {worker:?} does not hold chunk {chunk} of job {id:?}"
            ))),
        }
    }

    /// Telemetry snapshot of job `id`: live rows for an open job, the
    /// retained final rows for a recently finished/closed one, and a
    /// bare journal-derived snapshot (no worker rows — that state died
    /// with the process that held it) for anything older.
    pub fn job_metrics(&self, id: &str) -> Result<JobTelemetry> {
        {
            let mut jobs = self.lock_jobs();
            if let Some(oj) = jobs.get_mut(id) {
                // Sweep expiries first so `held` and the per-worker
                // expired counts are current as of this snapshot.
                let expired = oj.expire_leases(self.clock.now());
                if let Some(m) = &self.metrics {
                    m.expiries.add(expired);
                }
                return Ok(snapshot_open(id, oj, "open", self.cfg.speculate));
            }
        }
        if let Some(snap) = self
            .recent
            .lock()
            .expect("recent telemetry poisoned")
            .iter()
            .find(|(rid, _)| rid == id)
            .map(|(_, snap)| snap.clone())
        {
            return Ok(snap);
        }
        let st = self.store.status(id)?;
        Ok(JobTelemetry {
            id: id.to_string(),
            state: if st.complete { "done" } else { "closed" }.to_string(),
            chunks_done: st.chunks_done as u64,
            chunks_total: st.chunks_total as u64,
            terms_done: st.terms_done,
            terms_total: st.terms_total,
            tps_milli: 0,
            eta_ms: None,
            speculate: self.cfg.speculate,
            calib: st
                .geom
                .map_or(CalibState::Off, |(_, chunks)| CalibState::Chosen { chunks }),
            workers: Vec::new(),
        })
    }

    /// Retain a finished/closed job's final telemetry (bounded ring).
    fn remember(&self, snap: JobTelemetry) {
        let mut recent = self.recent.lock().expect("recent telemetry poisoned");
        recent.retain(|(id, _)| id != &snap.id);
        if recent.len() == RECENT_TELEMETRY_CAP {
            recent.pop_front();
        }
        recent.push_back((snap.id.clone(), snap));
    }

    /// Close an open fleet job (cooperative pause): stop granting,
    /// clear its fleet marker (so unpinned grants won't silently
    /// re-adopt a cancelled job), release its run lock. Journaled
    /// chunks survive — a job-pinned `LEASE GRANT`, `JOB RESUME`, or
    /// `raddet job resume` picks the sweep up from the journal.
    /// Returns whether the job was open.
    pub fn close(&self, id: &str) -> bool {
        let snap = self
            .lock_jobs()
            .remove(id)
            .map(|oj| snapshot_open(id, &oj, "closed", self.cfg.speculate));
        match snap {
            Some(snap) => {
                self.remember(snap);
                self.clear_fleet_marker(id);
                true
            }
            None => false,
        }
    }
}

/// Build a [`JobTelemetry`] snapshot from an in-memory [`OpenJob`].
/// `held` lease counts are only meaningful while the job is `open`.
fn snapshot_open(id: &str, oj: &OpenJob, state: &str, speculate: Option<u32>) -> JobTelemetry {
    let terms_done: u128 = oj.completed.values().map(|r| r.terms as u128).sum();
    let mut workers = oj.workers.clone();
    if state == "open" {
        for entries in oj.leases.values() {
            for e in entries {
                workers.entry(e.worker.clone()).or_default().held += 1;
            }
        }
    }
    let calib = match (oj.geom, oj.calib) {
        (Some((_, chunks)), _) => CalibState::Chosen { chunks },
        (None, Some(want)) => CalibState::Measuring {
            done: (0..want).filter(|i| oj.completed.contains_key(i)).count() as u64,
            want,
        },
        (None, None) => CalibState::Off,
    };
    let tps_milli = workers
        .values()
        .fold(0u64, |acc, row| acc.saturating_add(row.ewma_mtps));
    let eta_ms = (tps_milli > 0).then(|| {
        let remaining = oj.total_terms.saturating_sub(terms_done);
        (remaining.saturating_mul(1_000_000) / tps_milli as u128).min(u64::MAX as u128) as u64
    });
    JobTelemetry {
        id: id.to_string(),
        state: state.to_string(),
        chunks_done: oj.completed.len() as u64,
        chunks_total: oj.plan.len() as u64,
        terms_done,
        terms_total: oj.total_terms,
        tps_milli,
        eta_ms,
        speculate,
        calib,
        workers: workers.into_iter().collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::SimClock;
    use crate::jobs::{JobRunner, JobValue, RunnerConfig};
    use crate::matrix::gen;
    use crate::testkit::TestRng;

    /// Table over a virtual clock: expiry tests advance time instead of
    /// sleeping, so they are instant and can never race the wall clock.
    fn tmp_table(tag: &str, ttl: Duration) -> (Arc<SimClock>, LeaseTable) {
        let store =
            JobStore::open(crate::testkit::scratch_dir(&format!("fleet-{tag}"))).unwrap();
        let clock = SimClock::new();
        let table = LeaseTable::with_clock(
            store,
            FleetConfig { lease_ttl: ttl, default_chunks: 6, ..Default::default() },
            clock.clone(),
        );
        (clock, table)
    }

    /// Like [`tmp_table`] but with the caller's full [`FleetConfig`]
    /// and a registry, for the speculation / calibration tests.
    fn tmp_table_cfg(tag: &str, cfg: FleetConfig) -> (Arc<SimClock>, Arc<Registry>, LeaseTable) {
        let store =
            JobStore::open(crate::testkit::scratch_dir(&format!("fleet-{tag}"))).unwrap();
        let clock = SimClock::new();
        let registry = Arc::new(Registry::new());
        let table =
            LeaseTable::with_clock(store, cfg, clock.clone()).with_registry(&registry);
        (clock, registry, table)
    }

    fn submit_f64(table: &LeaseTable, seed: u64) -> String {
        let a = gen::uniform(&mut TestRng::from_seed(seed), 3, 9, -1.0, 1.0);
        table.submit(JobPayload::F64(a), JobEngine::Prefix).unwrap()
    }

    /// Compute a granted chunk the way a worker would.
    fn compute(spec: &JobSpec, chunk: Chunk) -> ChunkRecord {
        let (m, n) = spec.shape();
        let table = crate::combin::PascalTable::new(n as u64, m as u64).unwrap();
        let mut runner = spec.runner();
        let (partial, wm) = runner.run_chunk(spec.payload.as_lease(), &table, chunk).unwrap();
        ChunkRecord { value: partial.into(), terms: wm.terms, micros: 1 }
    }

    #[test]
    fn grant_complete_drains_to_done_matching_inprocess_bits() {
        let (_clock, table) = tmp_table("drain", Duration::from_secs(10));
        let id = submit_f64(&table, 61);
        // Reference: the identical spec run by the in-process runner.
        let spec = {
            let g = match table.grant("w0", Some(id.as_str()), |_| true).unwrap() {
                GrantOutcome::Granted(g) => g,
                other => panic!("{other:?}"),
            };
            let spec = g.spec.clone().unwrap();
            table.abandon("w0", &id, g.chunk_index).unwrap();
            spec
        };
        let ref_store =
            JobStore::open(crate::testkit::scratch_dir("fleet-drain-ref")).unwrap();
        let ref_id = ref_store.create(&spec).unwrap();
        let ref_out = JobRunner::new(RunnerConfig::default())
            .run(&ref_store, &ref_id)
            .unwrap();
        let want = ref_out.status.value.unwrap();

        // Drain all chunks through grant/complete.
        let mut finished = false;
        while !finished {
            let g = match table.grant("w1", Some(id.as_str()), |_| true).unwrap() {
                GrantOutcome::Granted(g) => g,
                other => panic!("{other:?}"),
            };
            let rec = compute(g.spec.as_ref().unwrap_or(&spec), g.chunk);
            match table.complete("w1", &id, g.chunk_index, rec).unwrap() {
                CompleteOutcome::Accepted { finished: f, .. } => finished = f,
                other => panic!("{other:?}"),
            }
        }
        assert!(matches!(
            table.grant("w1", Some(id.as_str()), |_| true).unwrap(),
            GrantOutcome::Complete
        ));
        let st = table.store().status(&id).unwrap();
        assert!(st.complete);
        match (st.value.unwrap(), want) {
            (JobValue::F64(a), JobValue::F64(b)) => assert_eq!(a.to_bits(), b.to_bits()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn big_job_drains_to_the_inprocess_value() {
        use crate::scalar::BigInt;
        let (_clock, table) = tmp_table("big-drain", Duration::from_secs(10));
        // Entries large enough that only the big scalar can finish.
        let a = gen::integer(
            &mut TestRng::from_seed(68),
            6,
            8,
            -900_000_000,
            900_000_000,
        );
        let want: BigInt = crate::linalg::radic_det_generic(&a).unwrap();
        let id = table.submit(JobPayload::Big(a), JobEngine::Prefix).unwrap();
        let mut spec: Option<JobSpec> = None;
        loop {
            let g = match table.grant("w1", Some(id.as_str()), |_| spec.is_none()).unwrap() {
                GrantOutcome::Granted(g) => g,
                GrantOutcome::Complete => break,
                other => panic!("{other:?}"),
            };
            if let Some(s) = g.spec {
                spec = Some(s);
            }
            let rec = compute(spec.as_ref().unwrap(), g.chunk);
            assert!(matches!(&rec.value, JobValue::Big(_)), "{rec:?}");
            table.complete("w1", &id, g.chunk_index, rec).unwrap();
        }
        match table.store().status(&id).unwrap().value.unwrap() {
            JobValue::Big(v) => {
                assert_eq!(v, want);
                assert_eq!(v.to_i128(), None, "the sweep genuinely needed big");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn expired_lease_is_regranted_and_late_complete_rejected() {
        let (clock, table) = tmp_table("expiry", Duration::from_millis(20));
        let id = submit_f64(&table, 62);
        let ga = match table.grant("wa", Some(id.as_str()), |_| true).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        let spec = ga.spec.clone().unwrap();
        // wa stops renewing; past the TTL the same chunk goes to wb.
        clock.advance(Duration::from_millis(60));
        let gb = match table.grant("wb", Some(id.as_str()), |_| false).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        assert_eq!(gb.chunk_index, ga.chunk_index, "expired chunk reassigned first");
        let rec = compute(&spec, gb.chunk);
        assert!(matches!(
            table.complete("wb", &id, gb.chunk_index, rec.clone()).unwrap(),
            CompleteOutcome::Accepted { .. }
        ));
        // wa's late duplicate is rejected and journals nothing…
        let before = table.store().status(&id).unwrap().chunks_done;
        let err = table
            .complete("wa", &id, ga.chunk_index, rec.clone())
            .unwrap_err();
        assert!(err.to_string().contains("lease lost"), "{err}");
        assert_eq!(table.store().status(&id).unwrap().chunks_done, before);
        // …while wb's retry is acknowledged idempotently.
        assert!(matches!(
            table.complete("wb", &id, gb.chunk_index, rec).unwrap(),
            CompleteOutcome::Duplicate { .. }
        ));
        assert_eq!(table.store().status(&id).unwrap().chunks_done, before);
    }

    #[test]
    fn renewal_keeps_a_lease_alive() {
        let (clock, table) = tmp_table("renew", Duration::from_millis(200));
        let id = submit_f64(&table, 63);
        let g = match table.grant("wa", Some(id.as_str()), |_| true).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        for _ in 0..3 {
            clock.advance(Duration::from_millis(60));
            table.renew("wa", &id, g.chunk_index, None).unwrap();
        }
        // t = 180 ms with the last renewal reaching to 380 ms: advance
        // well past the *original* 200 ms TTL — the chunk is still
        // wa's, so a rival grant gets a different chunk.
        clock.advance(Duration::from_millis(120));
        let gb = match table.grant("wb", Some(id.as_str()), |_| false).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        assert_ne!(gb.chunk_index, g.chunk_index);
        // A stranger cannot renew or abandon wa's lease.
        assert!(table.renew("wb", &id, g.chunk_index, None).is_err());
        assert!(table.abandon("wb", &id, g.chunk_index).is_err());
    }

    #[test]
    fn complete_validates_terms_and_kind() {
        let (_clock, table) = tmp_table("validate", Duration::from_secs(10));
        let id = submit_f64(&table, 64);
        let g = match table.grant("wa", Some(id.as_str()), |_| true).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        let good = compute(g.spec.as_ref().unwrap(), g.chunk);
        // Wrong term count.
        let bad_terms = ChunkRecord { terms: good.terms + 1, ..good.clone() };
        assert!(table.complete("wa", &id, g.chunk_index, bad_terms).is_err());
        // Wrong value scalar for an f64 job — either exact kind.
        for wrong in [
            JobValue::Exact(1),
            JobValue::Big(crate::scalar::BigInt::from_i64(1)),
        ] {
            let bad_kind = ChunkRecord { value: wrong, ..good.clone() };
            let err = table.complete("wa", &id, g.chunk_index, bad_kind).unwrap_err();
            assert!(err.to_string().contains("scalar"), "{err}");
        }
        // Out-of-plan index.
        assert!(table.complete("wa", &id, 10_000, good.clone()).is_err());
        // The lease survives the rejections and the real record lands.
        assert!(matches!(
            table.complete("wa", &id, g.chunk_index, good).unwrap(),
            CompleteOutcome::Accepted { .. }
        ));
    }

    #[test]
    fn unknown_and_closed_jobs_are_errors() {
        let (_clock, table) = tmp_table("unknown", Duration::from_secs(10));
        assert!(table.grant("wa", Some("job-nope"), |_| true).is_err());
        assert!(table.renew("wa", "job-nope", 0, None).is_err());
        let id = submit_f64(&table, 65);
        assert!(table.close(&id));
        assert!(!table.close(&id), "close is not idempotent-true");
        // Closed ⇒ leasing verbs on it fail until re-opened…
        assert!(table.renew("wa", &id, 0, None).is_err());
        // …and a grant lazily re-opens it.
        assert!(matches!(
            table.grant("wa", Some(id.as_str()), |_| true).unwrap(),
            GrantOutcome::Granted(_)
        ));
    }

    #[test]
    fn unpinned_grant_adopts_fleet_jobs_after_restart_and_respects_cancel() {
        let dir = crate::testkit::scratch_dir("fleet-marker");
        let store = JobStore::open(&dir).unwrap();
        let cfg = FleetConfig {
            lease_ttl: Duration::from_secs(10),
            default_chunks: 6,
            ..Default::default()
        };
        let t1 = LeaseTable::new(store.clone(), cfg);
        let a = gen::uniform(&mut TestRng::from_seed(67), 3, 9, -1.0, 1.0);
        let id = t1.submit(JobPayload::F64(a), JobEngine::Prefix).unwrap();
        // "Server restart": a fresh table over the same store, empty
        // in-memory state; the old process's lock must be gone first.
        drop(t1);
        let t2 = LeaseTable::new(store.clone(), cfg);
        match t2.grant("wx", None, |_| true).unwrap() {
            GrantOutcome::Granted(g) => {
                assert_eq!(g.job, id, "marker-led adoption of the interrupted sweep");
                assert!(g.spec.is_some());
            }
            other => panic!("{other:?}"),
        }
        // Cancel clears the marker: yet another "restarted server"
        // sees nothing to adopt without naming the job.
        assert!(t2.close(&id));
        let t3 = LeaseTable::new(store, cfg);
        assert!(matches!(
            t3.grant("wy", None, |_| true).unwrap(),
            GrantOutcome::Idle
        ));
        // Naming it still re-opens (explicit resumption).
        assert!(matches!(
            t3.grant("wy", Some(id.as_str()), |_| true).unwrap(),
            GrantOutcome::Granted(_)
        ));
    }

    #[test]
    fn close_releases_the_run_lock_for_inprocess_resume() {
        let (_clock, table) = tmp_table("close-lock", Duration::from_secs(10));
        let id = submit_f64(&table, 66);
        // While open, the run lock blocks an in-process runner.
        assert!(table.store().lock_job(&id).is_err());
        assert!(table.close(&id));
        let out = JobRunner::new(RunnerConfig::default())
            .run(table.store(), &id)
            .unwrap();
        assert!(out.status.complete);
        // A grant on the finished job reports Complete.
        assert!(matches!(
            table.grant("wa", Some(id.as_str()), |_| true).unwrap(),
            GrantOutcome::Complete
        ));
    }

    fn row(snap: &JobTelemetry, worker: &str) -> WorkerRow {
        snap.workers
            .iter()
            .find(|(w, _)| w == worker)
            .unwrap_or_else(|| panic!("no row for {worker} in {snap:?}"))
            .1
            .clone()
    }

    #[test]
    fn telemetry_attributes_expiry_duplicates_and_throughput_per_worker() {
        let (clock, table) = tmp_table("telemetry", Duration::from_millis(20));
        let id = submit_f64(&table, 71);
        let ga = match table.grant("wa", Some(id.as_str()), |_| true).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        let spec = ga.spec.clone().unwrap();
        // wa goes silent; past the TTL its chunk is re-granted to wb
        // and the expiry is attributed to wa.
        clock.advance(Duration::from_millis(60));
        let gb = match table.grant("wb", Some(id.as_str()), |_| false).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        assert_eq!(gb.chunk_index, ga.chunk_index);
        // 5 ms of (virtual) compute before delivery: wb's grant→complete
        // span is pure clock arithmetic, so its EWMA is deterministic.
        clock.advance(Duration::from_millis(5));
        let rec = compute(&spec, gb.chunk);
        assert!(matches!(
            table.complete("wb", &id, gb.chunk_index, rec.clone()).unwrap(),
            CompleteOutcome::Accepted { .. }
        ));
        assert!(matches!(
            table.complete("wb", &id, gb.chunk_index, rec).unwrap(),
            CompleteOutcome::Duplicate { .. }
        ));
        let snap = table.job_metrics(&id).unwrap();
        assert_eq!(snap.state, "open");
        assert_eq!(snap.chunks_done, 1);
        assert_eq!(snap.chunks_total, 6);
        let wa = row(&snap, "wa");
        assert_eq!((wa.held, wa.expired, wa.completed, wa.ewma_mtps), (0, 1, 0, 0));
        let wb = row(&snap, "wb");
        assert_eq!((wb.held, wb.completed, wb.duplicates, wb.expired), (0, 1, 1, 0));
        // terms over 5000 µs, in milli-terms/sec.
        let expected = snap.terms_done as u64 * 1_000_000_000 / 5_000;
        assert_eq!(wb.ewma_mtps, expected);
        assert_eq!(snap.tps_milli, expected);
        let eta = snap.eta_ms.unwrap();
        let remaining = (snap.terms_total - snap.terms_done) as u64;
        assert_eq!(eta, remaining * 1_000_000 / expected);
    }

    #[test]
    fn job_metrics_retains_finished_jobs_and_falls_back_after_restart() {
        let (_clock, table) = tmp_table("telemetry-done", Duration::from_secs(10));
        let id = submit_f64(&table, 72);
        let mut spec: Option<JobSpec> = None;
        loop {
            let g = match table.grant("w1", Some(id.as_str()), |_| spec.is_none()).unwrap() {
                GrantOutcome::Granted(g) => g,
                GrantOutcome::Complete => break,
                other => panic!("{other:?}"),
            };
            if let Some(s) = g.spec {
                spec = Some(s);
            }
            let rec = compute(spec.as_ref().unwrap(), g.chunk);
            table.complete("w1", &id, g.chunk_index, rec).unwrap();
        }
        // The OpenJob is gone (journal closed, lock released), but the
        // final telemetry is retained for METRICS JOB.
        let snap = table.job_metrics(&id).unwrap();
        assert_eq!(snap.state, "done");
        assert_eq!(snap.chunks_done, snap.chunks_total);
        assert_eq!(snap.terms_done, snap.terms_total);
        let w1 = row(&snap, "w1");
        assert_eq!(w1.completed, snap.chunks_total);
        assert_eq!(w1.held, 0);
        // A fresh table over the same store (server restart) lost the
        // rows; the journal-derived fallback still answers.
        let t2 = LeaseTable::new(table.store().clone(), FleetConfig::default());
        let bare = t2.job_metrics(&id).unwrap();
        assert_eq!(bare.state, "done");
        assert_eq!(bare.chunks_done, bare.chunks_total);
        assert!(bare.workers.is_empty());
        assert_eq!(bare.eta_ms, None);
        // Unknown ids stay errors.
        assert!(table.job_metrics("job-nope").is_err());
    }

    #[test]
    fn renew_reports_feed_the_throughput_ewma() {
        let (_clock, table) = tmp_table("telemetry-renew", Duration::from_secs(10));
        let id = submit_f64(&table, 73);
        let g = match table.grant("wa", Some(id.as_str()), |_| true).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        // 1000 terms in 1000 µs = 1e6 terms/sec = 1e9 milli-terms/sec.
        table.renew("wa", &id, g.chunk_index, Some((1_000, 1_000))).unwrap();
        assert_eq!(row(&table.job_metrics(&id).unwrap(), "wa").ewma_mtps, 1_000_000_000);
        // Reports are cumulative: this one contributes its delta
        // (1000 terms over 2000 µs = 5e8), EWMA-blended 3:1.
        table.renew("wa", &id, g.chunk_index, Some((2_000, 3_000))).unwrap();
        assert_eq!(row(&table.job_metrics(&id).unwrap(), "wa").ewma_mtps, 875_000_000);
        // A regressing report (worker restarted its counters) is
        // absorbed by the saturating delta — no panic, no update.
        table.renew("wa", &id, g.chunk_index, Some((1, 1))).unwrap();
        assert_eq!(row(&table.job_metrics(&id).unwrap(), "wa").ewma_mtps, 875_000_000);
    }

    #[test]
    fn fleet_counters_land_in_the_registry() {
        let store =
            JobStore::open(crate::testkit::scratch_dir("fleet-registry")).unwrap();
        let clock = SimClock::new();
        let registry = Arc::new(Registry::new());
        let table = LeaseTable::with_clock(
            store,
            FleetConfig {
                lease_ttl: Duration::from_millis(20),
                default_chunks: 6,
                ..Default::default()
            },
            clock.clone(),
        )
        .with_registry(&registry);
        let id = submit_f64(&table, 74);
        let g = match table.grant("wa", Some(id.as_str()), |_| true).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        let spec = g.spec.clone().unwrap();
        table.renew("wa", &id, g.chunk_index, None).unwrap();
        let rec = compute(&spec, g.chunk);
        table.complete("wa", &id, g.chunk_index, rec.clone()).unwrap();
        table.complete("wa", &id, g.chunk_index, rec).unwrap(); // duplicate
        let g2 = match table.grant("wa", Some(id.as_str()), |_| false).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        table.abandon("wa", &id, g2.chunk_index).unwrap();
        let g3 = match table.grant("wa", Some(id.as_str()), |_| false).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        clock.advance(Duration::from_millis(60));
        // g3's lease lapses during this grant's sweep.
        let g4 = match table.grant("wb", Some(id.as_str()), |_| false).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        assert_eq!(g4.chunk_index, g3.chunk_index);
        let snap = registry.snapshot();
        assert_eq!(snap.get("fleet_grants_total"), Some("4"));
        assert_eq!(snap.get("fleet_renews_total"), Some("1"));
        assert_eq!(snap.get("fleet_completes_total"), Some("1"));
        assert_eq!(snap.get("fleet_duplicates_total"), Some("1"));
        assert_eq!(snap.get("fleet_abandons_total"), Some("1"));
        assert_eq!(snap.get("fleet_expiries_total"), Some("1"));
        // The store's fs was rewrapped in MeteredFs on the table's sim
        // clock: journal appends are counted, with zero virtual latency.
        assert!(snap.get("fs_append_us_count").is_some_and(|v| v != "0"));
        assert_eq!(snap.get("fs_append_us_sum"), Some("0"));
    }

    #[test]
    fn calibration_journals_geom_and_replans_the_remainder() {
        let cfg = FleetConfig {
            lease_ttl: Duration::from_secs(10),
            default_chunks: 6,
            calib_chunks: 2,
            calib_target_ms: 500,
            ..Default::default()
        };
        let (_clock, _registry, table) = tmp_table_cfg("calib", cfg);
        let a = gen::integer(&mut TestRng::from_seed(81), 3, 9, -3, 3);
        let id = table.submit(JobPayload::Exact(a), JobEngine::Prefix).unwrap();

        // Measuring: grants stay inside the 2-chunk calibration prefix.
        let g0 = match table.grant("wa", Some(id.as_str()), |_| true).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        assert_eq!(g0.chunk_index, 0);
        let spec = g0.spec.clone().unwrap();
        let snap = table.job_metrics(&id).unwrap();
        assert_eq!(snap.calib, CalibState::Measuring { done: 0, want: 2 });
        assert_eq!(snap.chunks_total, 6, "SPEC geometry until calibration ends");

        // Reference: the identical spec swept on the base geometry in
        // one process (integer composition is associative, so the
        // re-chunked remainder cannot change the value).
        let ref_store =
            JobStore::open(crate::testkit::scratch_dir("fleet-calib-ref")).unwrap();
        let rid = ref_store.create(&spec).unwrap();
        JobRunner::new(RunnerConfig::default()).run(&ref_store, &rid).unwrap();
        let reference = ref_store.load(&rid).unwrap().done.unwrap();

        table.complete("wa", &id, 0, compute(&spec, g0.chunk)).unwrap();
        let g1 = match table.grant("wa", Some(id.as_str()), |_| false).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        assert_eq!(g1.chunk_index, 1, "measurement pass fills the prefix in order");
        table.complete("wa", &id, 1, compute(&spec, g1.chunk)).unwrap();

        // The next grant finishes calibration: the GEOM record lands
        // and the remainder is re-partitioned. `compute` stamps 1 µs
        // per chunk, so the measured rate is absurdly fast and the
        // whole remainder collapses into one chunk.
        let g2 = match table.grant("wa", Some(id.as_str()), |_| false).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        assert_eq!(g2.chunk_index, 2);
        let snap = table.job_metrics(&id).unwrap();
        assert_eq!(snap.calib, CalibState::Chosen { chunks: 1 });
        assert_eq!(snap.chunks_total, 3, "2 calibration chunks + 1 remainder");
        assert!(table.events().iter().any(|e| e.kind == "calibrate"), "{:?}", table.events());

        table.complete("wa", &id, 2, compute(&spec, g2.chunk)).unwrap();
        let st = table.store().status(&id).unwrap();
        assert!(st.complete);
        assert_eq!(st.geom, Some((2, 1)));
        assert_eq!(st.value.unwrap().encode(), reference.0.encode());

        // The journal carries the chosen geometry and exactly one
        // record per (re-chunked) plan index.
        let records = Journal::replay(&table.store().journal_path(&id).unwrap()).unwrap();
        assert!(records
            .iter()
            .any(|r| matches!(r, Record::Geom { calib: 2, chunks: 1 })));
        let mut seen = std::collections::BTreeSet::new();
        for r in &records {
            if let Record::Chunk { index, .. } = r {
                assert!(seen.insert(*index), "chunk {index} journaled twice");
            }
        }
        assert_eq!(seen.len(), 3);
    }

    /// The stale-worker / hostile-client hole: while calibration is
    /// active, a COMPLETE for a chunk past the prefix (a grant issued
    /// before `--calib-chunks` was enabled, or a fabricated index)
    /// must be rejected *before* anything reaches the journal — a
    /// CHUNK record outside the prefix lands before the GEOM and
    /// violates the journal's structural rule, turning every later
    /// load of the job into `JournalCorrupt`.
    #[test]
    fn complete_outside_calibration_prefix_is_rejected() {
        let cfg = FleetConfig {
            lease_ttl: Duration::from_secs(10),
            default_chunks: 6,
            calib_chunks: 2,
            calib_target_ms: 500,
            ..Default::default()
        };
        let (_clock, _registry, table) = tmp_table_cfg("calib-bound", cfg);
        let a = gen::integer(&mut TestRng::from_seed(83), 3, 9, -3, 3);
        let id = table.submit(JobPayload::Exact(a), JobEngine::Prefix).unwrap();
        let g0 = match table.grant("wa", Some(id.as_str()), |_| true).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        assert_eq!(g0.chunk_index, 0);
        let spec = g0.spec.clone().unwrap();

        // The out-of-prefix delivery bounces with a calibration error
        // and leaves no trace in the journal. (Pre-fix it was accepted
        // via the expired-lease path: chunk 3 has no active lease and
        // sits inside the 6-chunk SPEC plan.)
        let rec0 = compute(&spec, g0.chunk);
        let err = table.complete("wz", &id, 3, rec0.clone()).unwrap_err();
        assert!(err.to_string().contains("calibration prefix"), "{err}");
        let records = Journal::replay(&table.store().journal_path(&id).unwrap()).unwrap();
        assert!(
            !records.iter().any(|r| matches!(r, Record::Chunk { index: 3, .. })),
            "rejected delivery must not be journaled"
        );

        // Calibration then finishes undisturbed and the sweep drains to
        // a loadable, complete journal with the chosen geometry.
        table.complete("wa", &id, 0, rec0).unwrap();
        loop {
            match table.grant("wa", Some(id.as_str()), |_| false).unwrap() {
                GrantOutcome::Granted(g) => {
                    table.complete("wa", &id, g.chunk_index, compute(&spec, g.chunk)).unwrap();
                }
                GrantOutcome::Complete => break,
                other => panic!("{other:?}"),
            }
        }
        let st = table.store().status(&id).unwrap();
        assert!(st.complete);
        assert_eq!(st.geom, Some((2, 1)));
    }

    /// Defence-in-depth behind the COMPLETE bound: a journal that
    /// *already* holds a chunk outside the calibration prefix (written
    /// by a server from before the bound existed) must make the table
    /// abandon calibration — keeping the SPEC plan, like the resumed
    /// sweep case in `open_entry` — rather than append a GEOM record
    /// the structural rule forbids and self-corrupt the journal.
    #[test]
    fn calibration_abandons_when_journal_already_ran_past_the_prefix() {
        let cfg = FleetConfig {
            lease_ttl: Duration::from_secs(10),
            default_chunks: 6,
            calib_chunks: 2,
            calib_target_ms: 500,
            ..Default::default()
        };
        let (_clock, _registry, table) = tmp_table_cfg("calib-abandon", cfg);
        let a = gen::integer(&mut TestRng::from_seed(84), 3, 9, -3, 3);
        let id = table.submit(JobPayload::Exact(a), JobEngine::Prefix).unwrap();
        let g0 = match table.grant("wa", Some(id.as_str()), |_| true).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        let spec = g0.spec.clone().unwrap();
        table.complete("wa", &id, 0, compute(&spec, g0.chunk)).unwrap();

        // Inject a journaled out-of-prefix chunk directly, the way an
        // older (pre-bound) server would have left it.
        {
            let mut jobs = table.lock_jobs();
            let oj = jobs.get_mut(&id).unwrap();
            let rec = compute(&spec, oj.plan[3]);
            oj.journal.append(&Record::Chunk { index: 3, rec: rec.clone() }).unwrap();
            oj.completed.insert(3, rec);
        }

        // The next grant would have been the GEOM append point; instead
        // calibration is abandoned and the full SPEC plan opens up.
        let g1 = match table.grant("wa", Some(id.as_str()), |_| false).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        assert_eq!(g1.chunk_index, 1, "bound lifted, lowest free SPEC chunk granted");
        let snap = table.job_metrics(&id).unwrap();
        assert_eq!(snap.calib, CalibState::Off);
        assert_eq!(snap.chunks_total, 6, "SPEC geometry kept");
        assert!(
            table.events().iter().any(|e| e.kind == "calibrate_abandon"),
            "{:?}",
            table.events()
        );

        // Drain the remaining SPEC chunks: the journal stays loadable
        // (no GEOM record ever lands) and the job completes.
        table.complete("wa", &id, 1, compute(&spec, g1.chunk)).unwrap();
        loop {
            match table.grant("wa", Some(id.as_str()), |_| false).unwrap() {
                GrantOutcome::Granted(g) => {
                    table.complete("wa", &id, g.chunk_index, compute(&spec, g.chunk)).unwrap();
                }
                GrantOutcome::Complete => break,
                other => panic!("{other:?}"),
            }
        }
        let records = Journal::replay(&table.store().journal_path(&id).unwrap()).unwrap();
        assert!(!records.iter().any(|r| matches!(r, Record::Geom { .. })));
        let st = table.store().status(&id).unwrap();
        assert!(st.complete);
        assert_eq!(st.geom, None);
    }

    #[test]
    fn speculative_release_races_and_first_complete_wins() {
        let cfg = FleetConfig {
            lease_ttl: Duration::from_secs(10),
            default_chunks: 2,
            speculate: Some(2),
            ..Default::default()
        };
        let (clock, registry, table) = tmp_table_cfg("speculate", cfg);
        let id = submit_f64(&table, 82);
        let ga = match table.grant("wa", Some(id.as_str()), |_| true).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        assert_eq!(ga.chunk_index, 0);
        let spec = ga.spec.clone().unwrap();
        let gb = match table.grant("wb", Some(id.as_str()), |_| false).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        assert_eq!(gb.chunk_index, 1);
        clock.advance(Duration::from_millis(5));
        assert!(matches!(
            table.complete("wb", &id, 1, compute(&spec, gb.chunk)).unwrap(),
            CompleteOutcome::Accepted { finished: false, .. }
        ));
        // wa has produced no sample and its lease is young: nothing to
        // speculate on yet.
        assert!(matches!(
            table.grant("wb", Some(id.as_str()), |_| false).unwrap(),
            GrantOutcome::Idle
        ));
        // wa's renew reports a crawl — 10 terms in a full second — so
        // the fleet median (wb's EWMA) is far beyond 2× wa's.
        table.renew("wa", &id, 0, Some((10, 1_000_000))).unwrap();
        let gs = match table.grant("wb", Some(id.as_str()), |_| false).unwrap() {
            GrantOutcome::Granted(g) => g,
            other => panic!("{other:?}"),
        };
        assert_eq!(gs.chunk_index, 0, "straggler chunk re-leased speculatively");
        let rec0 = compute(&spec, gs.chunk);
        assert!(matches!(
            table.complete("wb", &id, 0, rec0.clone()).unwrap(),
            CompleteOutcome::Accepted { finished: true, .. }
        ));
        // The original holder's late delivery is a harmless duplicate
        // of the finished job — nothing journaled.
        assert!(matches!(
            table.complete("wa", &id, 0, rec0).unwrap(),
            CompleteOutcome::Duplicate { .. }
        ));

        let snap = registry.snapshot();
        assert_eq!(snap.get("fleet_release_grants_total"), Some("1"));
        assert_eq!(snap.get("fleet_release_wins_total"), Some("1"));
        assert_eq!(snap.get("fleet_release_losses_total"), Some("1"));
        let kinds: Vec<String> = table.events().into_iter().map(|e| e.kind).collect();
        assert!(kinds.iter().any(|k| k == "release_grant"), "{kinds:?}");
        assert!(kinds.iter().any(|k| k == "release_win"), "{kinds:?}");
        assert_eq!(table.job_metrics(&id).unwrap().speculate, Some(2));

        // Chunk conservation despite the double grant: one journaled
        // record per plan index.
        let records = Journal::replay(&table.store().journal_path(&id).unwrap()).unwrap();
        let mut seen = std::collections::BTreeSet::new();
        for r in &records {
            if let Record::Chunk { index, .. } = r {
                assert!(seen.insert(*index), "chunk {index} journaled twice");
            }
        }
        assert_eq!(seen.len(), 2);
        assert!(table.store().status(&id).unwrap().complete);
    }

    #[test]
    fn duplicate_attribution_requires_participation() {
        let (_clock, table) = tmp_table("dup-attrib", Duration::from_secs(10));
        let id = submit_f64(&table, 83);
        let mut spec: Option<JobSpec> = None;
        let mut rec0: Option<ChunkRecord> = None;
        loop {
            let g = match table.grant("wa", Some(id.as_str()), |_| spec.is_none()).unwrap() {
                GrantOutcome::Granted(g) => g,
                GrantOutcome::Complete => break,
                other => panic!("{other:?}"),
            };
            if let Some(s) = g.spec {
                spec = Some(s);
            }
            let rec = compute(spec.as_ref().unwrap(), g.chunk);
            rec0.get_or_insert_with(|| rec.clone());
            table.complete("wa", &id, g.chunk_index, rec).unwrap();
        }
        let rec0 = rec0.unwrap();
        // A sender that never participated retries against the finished
        // job: acknowledged idempotently, but no telemetry row is
        // invented for it.
        assert!(matches!(
            table.complete("wz", &id, 0, rec0.clone()).unwrap(),
            CompleteOutcome::Duplicate { .. }
        ));
        let snap = table.job_metrics(&id).unwrap();
        assert!(snap.workers.iter().all(|(w, _)| w != "wz"), "{snap:?}");
        // The actual participant's retry *is* attributed.
        assert!(matches!(
            table.complete("wa", &id, 0, rec0.clone()).unwrap(),
            CompleteOutcome::Duplicate { .. }
        ));
        assert_eq!(row(&table.job_metrics(&id).unwrap(), "wa").duplicates, 1);

        // Same rule inside an open job whose chunk was journaled before
        // this table opened it (completer identity not persisted): the
        // duplicate is acknowledged without attributing anyone.
        let store = table.store().clone();
        let id2 = store.create(spec.as_ref().unwrap()).unwrap();
        JobRunner::new(RunnerConfig { workers: 1, chunk_budget: Some(1) })
            .run(&store, &id2)
            .unwrap();
        assert!(table.open(&id2).unwrap());
        assert!(matches!(
            table.complete("wz", &id2, 0, rec0).unwrap(),
            CompleteOutcome::Duplicate { .. }
        ));
        let snap2 = table.job_metrics(&id2).unwrap();
        assert!(snap2.workers.is_empty(), "{snap2:?}");
    }
}
