//! The fleet worker loop behind `raddet worker --connect`.
//!
//! A worker is a plain TCP client of the determinant service: it claims
//! chunk leases (`LEASE GRANT`), reconstructs the job's bit-exact
//! matrix from the spec embedded in the first grant per job (later
//! grants say `CACHED`), evaluates each chunk with the
//! [`ChunkRunner`] the spec's engine tags select, and delivers the
//! partial (`LEASE COMPLETE`) in the journal's bit-pattern encoding.
//! A heartbeat thread on its own connection renews the held lease every
//! [`WorkerConfig::renew_every`], so chunks longer than the server's
//! TTL survive — and a worker that dies simply stops renewing, which is
//! exactly the signal the server's lease table needs to reassign.
//!
//! Delivery failures are benign by design: a `LEASE COMPLETE` rejected
//! because the lease expired and another worker finished the chunk is
//! counted in [`WorkerReport::rejected`] and the loop moves on — the
//! partial was deterministic, so nothing about the journal is at risk.

use crate::combin::{Chunk, PascalTable};
use crate::coordinator::ChunkRunner;
use crate::jobs::JobSpec;
use crate::service::{Client, GrantReply};
use crate::{Error, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Worker knobs.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// Worker id on the wire (job-id charset; it names this worker in
    /// lease ownership and error messages).
    pub id: String,
    /// Serve only this job (`None` ⇒ any open fleet job). A worker
    /// pinned to a job exits when the job completes.
    pub job: Option<String>,
    /// Idle poll interval when the server has nothing to lease.
    pub poll: Duration,
    /// Exit when the server reports no leasable chunk instead of
    /// polling for more work.
    pub exit_on_idle: bool,
    /// Complete at most this many chunks, then exit cleanly.
    pub max_chunks: Option<u64>,
    /// Upper bound on the heartbeat period for renewing the held
    /// lease. The effective cadence is `min(renew_every, ttl/3)` of
    /// the *granted* TTL, so a server running short leases is renewed
    /// fast enough automatically.
    pub renew_every: Duration,
    /// Failure injection for tests and ops drills: stop dead
    /// immediately after the Nth grant — the lease is neither computed,
    /// completed, nor abandoned, exactly like a worker crash. The
    /// server must recover it by TTL expiry.
    pub crash_after_grants: Option<u64>,
}

impl WorkerConfig {
    /// Defaults for worker `id`: serve any job, poll every 500 ms,
    /// renew every 5 s, run until stopped.
    pub fn new(id: impl Into<String>) -> Self {
        Self {
            id: id.into(),
            job: None,
            poll: Duration::from_millis(500),
            exit_on_idle: false,
            max_chunks: None,
            renew_every: Duration::from_secs(5),
            crash_after_grants: None,
        }
    }
}

/// What one worker run achieved.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerReport {
    /// Chunks completed and accepted by the server.
    pub chunks: u64,
    /// Radić terms evaluated across accepted chunks.
    pub terms: u64,
    /// Completions the server rejected (lease lost to reassignment).
    pub rejected: u64,
    /// True when the run ended via [`WorkerConfig::crash_after_grants`].
    pub crashed: bool,
}

/// Per-job state a worker caches from the first grant's spec.
struct CachedJob {
    spec: JobSpec,
    table: PascalTable,
    runner: ChunkRunner,
}

impl CachedJob {
    fn build(spec: JobSpec) -> Result<CachedJob> {
        let (m, n) = spec.shape();
        let table = PascalTable::new(n as u64, m as u64)?;
        let runner = spec.runner();
        Ok(CachedJob { spec, table, runner })
    }
}

/// Renew the currently held lease from a second connection so the main
/// loop can stay buried in chunk compute. Each held lease carries its
/// own renew period (derived from the granted TTL). Renewal failures
/// are soft: the connection is rebuilt on the next tick, and if the
/// lease really is gone the eventual `LEASE COMPLETE` is the
/// authoritative verdict.
fn spawn_heartbeat(
    addr: String,
    worker: String,
    held: Arc<Mutex<Option<(String, u64, Duration)>>>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let tick = Duration::from_millis(20);
        let mut client: Option<Client> = None;
        let mut last = Instant::now();
        while !stop.load(Ordering::SeqCst) {
            std::thread::sleep(tick);
            let lease = held.lock().expect("held lease poisoned").clone();
            let Some((job, chunk, every)) = lease else { continue };
            if last.elapsed() < every {
                continue;
            }
            if client.is_none() {
                client = Client::connect(&addr).ok();
            }
            let renewed = client
                .as_mut()
                .is_some_and(|c| c.lease_renew(&worker, &job, chunk).is_ok());
            if !renewed {
                client = None;
            }
            last = Instant::now();
        }
    })
}

/// Join a running determinant server as a fleet worker and serve chunk
/// leases until stopped, idle-exhausted, or budget-bounded (see
/// [`WorkerConfig`]). `stop` makes the loop cooperative: raise it and
/// the worker finishes (and delivers) its in-flight chunk, then exits.
pub fn run_worker(addr: &str, cfg: &WorkerConfig, stop: &AtomicBool) -> Result<WorkerReport> {
    let mut client = Client::connect(addr)?;
    let mut jobs: HashMap<String, CachedJob> = HashMap::new();
    let mut report = WorkerReport::default();
    let mut grants: u64 = 0;
    let mut grant_errors: u32 = 0;
    let mut run_err: Option<Error> = None;

    let held: Arc<Mutex<Option<(String, u64, Duration)>>> = Arc::new(Mutex::new(None));
    let heartbeat_stop = Arc::new(AtomicBool::new(false));
    let heartbeat = spawn_heartbeat(
        addr.to_string(),
        cfg.id.clone(),
        Arc::clone(&held),
        Arc::clone(&heartbeat_stop),
    );

    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        if cfg.max_chunks.is_some_and(|cap| report.chunks >= cap) {
            break;
        }
        let reply = match client.lease_grant(&cfg.id, cfg.job.as_deref()) {
            Ok(r) => {
                grant_errors = 0;
                r
            }
            Err(e) => {
                // Transient conflicts (a just-released run lock still
                // visible) and dead connections (server restart) are
                // retried briefly before giving up. Reconnecting also
                // resets the server's per-connection spec cache, so
                // dropping ours keeps the two sides consistent.
                grant_errors += 1;
                if grant_errors > 50 {
                    run_err = Some(e);
                    break;
                }
                std::thread::sleep(cfg.poll);
                if let Ok(fresh) = Client::connect(addr) {
                    client = fresh;
                    jobs.clear();
                }
                continue;
            }
        };
        match reply {
            GrantReply::NoLease { reason } => {
                if reason == "complete" && cfg.job.is_some() {
                    break; // the one job we serve is done
                }
                if cfg.exit_on_idle {
                    break;
                }
                std::thread::sleep(cfg.poll);
            }
            GrantReply::Lease { job, chunk, start, len, ttl_ms, spec } => {
                grants += 1;
                if cfg.crash_after_grants.is_some_and(|cap| grants >= cap) {
                    // Die holding the lease: neither complete nor
                    // abandon — the server's TTL must recover it.
                    report.crashed = true;
                    break;
                }
                if let Some(spec) = spec {
                    match CachedJob::build(spec) {
                        Ok(cj) => {
                            jobs.insert(job.clone(), cj);
                        }
                        Err(e) => {
                            run_err = Some(e);
                            break;
                        }
                    }
                }
                let Some(cj) = jobs.get_mut(&job) else {
                    // `CACHED` for a spec this connection never saw
                    // (can only follow a server-side anomaly): give the
                    // lease back rather than compute blind.
                    let _ = client.lease_abandon(&cfg.id, &job, chunk);
                    std::thread::sleep(cfg.poll);
                    continue;
                };
                // Renew well inside the granted TTL whatever the
                // server's lease config is; cfg.renew_every only caps
                // how chatty the heartbeat may get.
                let renew_period = cfg
                    .renew_every
                    .min(Duration::from_millis((ttl_ms / 3).max(10)));
                *held.lock().expect("held lease poisoned") =
                    Some((job.clone(), chunk, renew_period));
                let t0 = Instant::now();
                let outcome =
                    cj.runner
                        .run_chunk(cj.spec.payload.as_lease(), &cj.table, Chunk { start, len });
                let micros = t0.elapsed().as_micros() as u64;
                *held.lock().expect("held lease poisoned") = None;
                match outcome {
                    Ok((partial, wm)) => {
                        match client.lease_complete(
                            &cfg.id,
                            &job,
                            chunk,
                            wm.terms,
                            micros,
                            partial.into(),
                        ) {
                            Ok(ack) => {
                                // A dup ack means some delivery of this
                                // chunk already counted (possibly by
                                // another worker after our lease
                                // expired) — counting it again would
                                // break chunk conservation.
                                if !ack.duplicate {
                                    report.chunks += 1;
                                    report.terms += wm.terms;
                                }
                                if ack.chunks_done == ack.chunks_total {
                                    // Job finished: drop its cached
                                    // matrix so a long-lived worker's
                                    // memory stays bounded by *live*
                                    // jobs, not every job ever served.
                                    jobs.remove(&job);
                                }
                            }
                            Err(_) => report.rejected += 1,
                        }
                    }
                    Err(e) => {
                        let _ = client.lease_abandon(&cfg.id, &job, chunk);
                        run_err = Some(e);
                        break;
                    }
                }
            }
        }
    }

    heartbeat_stop.store(true, Ordering::SeqCst);
    let _ = heartbeat.join();
    if report.crashed {
        drop(client); // no polite QUIT — simulate the crash faithfully
    } else {
        client.quit();
    }
    match run_err {
        Some(e) => Err(e),
        None => Ok(report),
    }
}
