//! The fleet worker: a step-wise lease-serving state machine
//! ([`Worker`]) plus the threaded loop behind `raddet worker --connect`
//! ([`run_worker`]).
//!
//! A worker is a plain client of the determinant service: it claims
//! chunk leases (`LEASE GRANT`), reconstructs the job's bit-exact
//! matrix from the spec embedded in the first grant per job (later
//! grants say `CACHED`), evaluates each chunk with the
//! [`ChunkRunner`] the spec's engine tags select, and delivers the
//! partial (`LEASE COMPLETE`) in the journal's bit-pattern encoding.
//!
//! [`Worker::step`] performs exactly one grant→compute→deliver cycle
//! and **never sleeps** — pacing decisions (idle poll, reconnect
//! back-off) are returned to the caller as [`WorkerEvent`]s. That split
//! is what the deterministic simulation fabric
//! ([`crate::testkit::sim`]) is built on: a seeded scheduler steps many
//! workers cooperatively and every interleaving is a replayable
//! function of the seed. [`run_worker`] is the production driver: real
//! TCP transport, wall clock, a poll sleep on idle, and a heartbeat
//! thread on its own connection renewing the held lease every
//! [`WorkerConfig::renew_every`], so chunks longer than the server's
//! TTL survive — and a worker that dies simply stops renewing, which is
//! exactly the signal the server's lease table needs to reassign.
//!
//! Delivery failures are benign by design: a `LEASE COMPLETE` rejected
//! because the lease expired and another worker finished the chunk is
//! counted in [`WorkerReport::rejected`] and the loop moves on — the
//! partial was deterministic, so nothing about the journal is at risk.

use crate::clock::{self, Clock};
use crate::combin::{Chunk, PascalTable};
use crate::coordinator::ChunkRunner;
use crate::jobs::journal::fnv1a64;
use crate::jobs::JobSpec;
use crate::retry::{Backoff, RetryPolicy};
use crate::service::{Client, GrantReply, TcpTransport, Transport};
use crate::{Error, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Worker knobs.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// Worker id on the wire (job-id charset; it names this worker in
    /// lease ownership and error messages).
    pub id: String,
    /// Serve only this job (`None` ⇒ any open fleet job). A worker
    /// pinned to a job exits when the job completes.
    pub job: Option<String>,
    /// Idle poll interval when the server has nothing to lease.
    pub poll: Duration,
    /// Exit when the server reports no leasable chunk instead of
    /// polling for more work.
    pub exit_on_idle: bool,
    /// Complete at most this many chunks, then exit cleanly.
    pub max_chunks: Option<u64>,
    /// Upper bound on the heartbeat period for renewing the held
    /// lease. The effective cadence is `min(renew_every, ttl/3)` of
    /// the *granted* TTL, so a server running short leases is renewed
    /// fast enough automatically.
    pub renew_every: Duration,
    /// Failure injection for tests and ops drills: stop dead
    /// immediately after the Nth grant — the lease is neither computed,
    /// completed, nor abandoned, exactly like a worker crash. The
    /// server must recover it by TTL expiry.
    pub crash_after_grants: Option<u64>,
    /// Slowness injection for straggler drills (`--throttle-ms`): sleep
    /// this long on the worker's clock inside every chunk's compute
    /// span, so the server-side throughput EWMA attributes the slowness
    /// to this worker and speculative re-lease can single it out. The
    /// deterministic sim injects slowness via network latency instead.
    pub throttle: Option<Duration>,
}

impl WorkerConfig {
    /// Defaults for worker `id`: serve any job, poll every 500 ms,
    /// renew every 5 s, run until stopped.
    pub fn new(id: impl Into<String>) -> Self {
        Self {
            id: id.into(),
            job: None,
            poll: Duration::from_millis(500),
            exit_on_idle: false,
            max_chunks: None,
            renew_every: Duration::from_secs(5),
            crash_after_grants: None,
            throttle: None,
        }
    }
}

/// What one worker run achieved.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerReport {
    /// Chunks completed and accepted by the server.
    pub chunks: u64,
    /// Radić terms evaluated across accepted chunks.
    pub terms: u64,
    /// Completions the server rejected (lease lost to reassignment).
    pub rejected: u64,
    /// True when the run ended via [`WorkerConfig::crash_after_grants`].
    pub crashed: bool,
}

/// What one [`Worker::step`] did — the scheduler's (and
/// [`run_worker`]'s) pacing signal. Steps never sleep; the driver
/// decides what an `Idle` or `Disconnected` step is worth in time.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WorkerEvent {
    /// Nothing leasable right now.
    Idle,
    /// The pinned job has finished; a pinned worker is done.
    JobComplete,
    /// A chunk was computed and delivered.
    Completed {
        /// The job id.
        job: String,
        /// Chunk index delivered.
        chunk: u64,
        /// The server acknowledged it as an idempotent re-delivery.
        duplicate: bool,
    },
    /// The server rejected the delivery (lease lost to reassignment).
    Rejected {
        /// The job id.
        job: String,
        /// Chunk index rejected.
        chunk: u64,
    },
    /// Failure injection fired: the worker died holding this lease
    /// (neither completed nor abandoned). The worker is terminal.
    Crashed {
        /// The job id.
        job: String,
        /// The chunk whose lease dies with the worker.
        chunk: u64,
    },
    /// The connection failed (or could not be re-established); the next
    /// step redials. After ~50 consecutive failures `step` errors out.
    Disconnected,
    /// [`WorkerConfig::max_chunks`] reached; the worker is done.
    BudgetExhausted,
}

/// Per-job state a worker caches from the first grant's spec.
struct CachedJob {
    spec: JobSpec,
    table: PascalTable,
    runner: ChunkRunner,
}

impl CachedJob {
    fn build(spec: JobSpec) -> Result<CachedJob> {
        let (m, n) = spec.shape();
        let table = PascalTable::new(n as u64, m as u64)?;
        let runner = spec.runner();
        Ok(CachedJob { spec, table, runner })
    }
}

/// The lease currently being computed: `(job, chunk, renew period)` —
/// shared with the heartbeat thread on the production path.
type HeldLease = Arc<Mutex<Option<(String, u64, Duration)>>>;

/// Cumulative `(terms, micros)` computed by this worker across all
/// chunks — shared with the heartbeat thread, which piggybacks the
/// running total onto each `LEASE RENEW` so the server can derive
/// per-worker throughput. Cumulative (not per-interval) on purpose: a
/// lost renew frame merely delays the next delta instead of losing work
/// from the server's tally.
type WorkTally = Arc<Mutex<(u64, u64)>>;

/// A step-wise fleet worker over any transport and clock.
pub struct Worker {
    cfg: WorkerConfig,
    transport: Arc<dyn Transport>,
    addr: String,
    clock: Arc<dyn Clock>,
    client: Option<Client>,
    jobs: HashMap<String, CachedJob>,
    report: WorkerReport,
    grants: u64,
    grant_errors: u32,
    held: HeldLease,
    work: WorkTally,
}

impl Worker {
    /// Dial `addr` over `transport` and build a worker. Fails fast when
    /// the first connection cannot be established (a typo'd address
    /// should error, not retry forever); later connection losses are
    /// retried via [`WorkerEvent::Disconnected`].
    pub fn connect(
        transport: Arc<dyn Transport>,
        addr: &str,
        cfg: WorkerConfig,
        clock: Arc<dyn Clock>,
    ) -> Result<Worker> {
        let conn = transport.connect(addr)?;
        Ok(Worker {
            cfg,
            transport,
            addr: addr.to_string(),
            clock,
            client: Some(Client::over(conn)),
            jobs: HashMap::new(),
            report: WorkerReport::default(),
            grants: 0,
            grant_errors: 0,
            held: Arc::new(Mutex::new(None)),
            work: Arc::new(Mutex::new((0, 0))),
        })
    }

    /// Progress so far (final report comes from [`Worker::finish`]).
    pub fn report(&self) -> WorkerReport {
        self.report
    }

    /// Handle to the held-lease slot for a heartbeat loop.
    fn held_handle(&self) -> HeldLease {
        Arc::clone(&self.held)
    }

    /// Handle to the cumulative work tally for a heartbeat loop.
    fn work_handle(&self) -> WorkTally {
        Arc::clone(&self.work)
    }

    /// A grant/connect failure: drop the connection (also resetting the
    /// spec caches on both sides — the server's is per-connection) and
    /// let the next step redial. Gives up after 50 consecutive
    /// failures.
    fn connection_failure(&mut self, e: Error) -> Result<WorkerEvent> {
        self.grant_errors += 1;
        self.client = None;
        self.jobs.clear();
        if self.grant_errors > 50 {
            return Err(e);
        }
        Ok(WorkerEvent::Disconnected)
    }

    /// One grant→compute→deliver cycle. Never sleeps, never blocks on
    /// time — pacing is the driver's job (see [`WorkerEvent`]).
    pub fn step(&mut self) -> Result<WorkerEvent> {
        if self.report.crashed {
            return Err(Error::Job(format!(
                "worker {:?} crashed and cannot be stepped",
                self.cfg.id
            )));
        }
        if self.cfg.max_chunks.is_some_and(|cap| self.report.chunks >= cap) {
            return Ok(WorkerEvent::BudgetExhausted);
        }
        if self.client.is_none() {
            match self.transport.connect(&self.addr) {
                Ok(conn) => self.client = Some(Client::over(conn)),
                Err(e) => return self.connection_failure(e),
            }
        }
        let reply = {
            let client = self.client.as_mut().expect("client ensured above");
            match client.lease_grant(&self.cfg.id, self.cfg.job.as_deref()) {
                Ok(r) => {
                    self.grant_errors = 0;
                    r
                }
                // Transient conflicts (a just-released run lock still
                // visible) and dead connections (server restart) are
                // retried; reconnecting also resets the server's
                // per-connection spec cache, so dropping ours keeps the
                // two sides consistent.
                Err(e) => return self.connection_failure(e),
            }
        };
        let (job, chunk, start, len, ttl_ms, spec) = match reply {
            GrantReply::NoLease { reason } => {
                if reason == "complete" && self.cfg.job.is_some() {
                    return Ok(WorkerEvent::JobComplete);
                }
                return Ok(WorkerEvent::Idle);
            }
            GrantReply::Lease { job, chunk, start, len, ttl_ms, spec } => {
                (job, chunk, start, len, ttl_ms, spec)
            }
        };
        self.grants += 1;
        if self.cfg.crash_after_grants.is_some_and(|cap| self.grants >= cap) {
            // Die holding the lease: neither complete nor abandon — the
            // server's TTL must recover it. No polite QUIT either: the
            // connection is torn down exactly as a crash would.
            self.report.crashed = true;
            self.client = None;
            return Ok(WorkerEvent::Crashed { job, chunk });
        }
        if let Some(spec) = spec {
            match CachedJob::build(spec) {
                Ok(cj) => {
                    self.jobs.insert(job.clone(), cj);
                }
                Err(e) => return Err(e),
            }
        }
        let Some(cj) = self.jobs.get_mut(&job) else {
            // `CACHED` for a spec this connection never saw (can only
            // follow a server-side anomaly): give the lease back rather
            // than compute blind.
            let client = self.client.as_mut().expect("client ensured above");
            let _ = client.lease_abandon(&self.cfg.id, &job, chunk);
            return Ok(WorkerEvent::Idle);
        };
        // Renew well inside the granted TTL whatever the server's lease
        // config is; cfg.renew_every only caps how chatty the heartbeat
        // may get.
        let renew_period = self
            .cfg
            .renew_every
            .min(Duration::from_millis((ttl_ms / 3).max(10)));
        *self.held.lock().expect("held lease poisoned") =
            Some((job.clone(), chunk, renew_period));
        let t0 = self.clock.now();
        let outcome =
            cj.runner
                .run_chunk(cj.spec.payload.as_lease(), &cj.table, Chunk { start, len });
        if let Some(d) = self.cfg.throttle {
            // Inside the compute span on purpose: the slowness must be
            // visible in this chunk's reported micros.
            self.clock.sleep(d);
        }
        let micros = self.clock.now().saturating_sub(t0).as_micros() as u64;
        *self.held.lock().expect("held lease poisoned") = None;
        match outcome {
            Ok((partial, wm)) => {
                {
                    // Tally the compute whether or not the delivery is
                    // accepted — the throughput report measures work this
                    // worker *did*, and a duplicate ack still cost it.
                    let mut work = self.work.lock().expect("work tally poisoned");
                    work.0 += wm.terms;
                    work.1 += micros;
                }
                let client = self.client.as_mut().expect("client ensured above");
                match client.lease_complete(
                    &self.cfg.id,
                    &job,
                    chunk,
                    wm.terms,
                    micros,
                    partial.into(),
                ) {
                    Ok(ack) => {
                        // A dup ack means some delivery of this chunk
                        // already counted (possibly by another worker
                        // after our lease expired) — counting it again
                        // would break chunk conservation.
                        if !ack.duplicate {
                            self.report.chunks += 1;
                            self.report.terms += wm.terms;
                        }
                        if ack.chunks_done == ack.chunks_total {
                            // Job finished: drop its cached matrix so a
                            // long-lived worker's memory stays bounded
                            // by *live* jobs, not every job ever served.
                            self.jobs.remove(&job);
                        }
                        Ok(WorkerEvent::Completed { job, chunk, duplicate: ack.duplicate })
                    }
                    Err(_) => {
                        self.report.rejected += 1;
                        Ok(WorkerEvent::Rejected { job, chunk })
                    }
                }
            }
            Err(e) => {
                let client = self.client.as_mut().expect("client ensured above");
                let _ = client.lease_abandon(&self.cfg.id, &job, chunk);
                Err(e)
            }
        }
    }

    /// End the run: QUIT politely (unless the worker "crashed" — then
    /// the connection was already torn down abruptly) and return the
    /// final report.
    pub fn finish(mut self) -> WorkerReport {
        if let Some(client) = self.client.take() {
            client.quit();
        }
        self.report
    }
}

/// Renew the currently held lease from a second connection so the main
/// loop can stay buried in chunk compute. Each held lease carries its
/// own renew period (derived from the granted TTL). Renewal failures
/// are soft: the connection is rebuilt on the next tick, and if the
/// lease really is gone the eventual `LEASE COMPLETE` is the
/// authoritative verdict.
fn spawn_heartbeat(
    transport: Arc<dyn Transport>,
    addr: String,
    worker: String,
    held: HeldLease,
    work: WorkTally,
    stop: Arc<AtomicBool>,
    clock: Arc<dyn Clock>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        // The tick sleeps on *real* time so `stop` stays responsive
        // even under a frozen SimClock (a virtual sleep with no
        // advancer would hang shutdown); the renew *cadence* reads the
        // clock seam, so under sim the heartbeat is idle by design —
        // sim steps are atomic with respect to virtual time.
        let tick = Duration::from_millis(20);
        let mut client: Option<Client> = None;
        let mut last = clock.now();
        // Redials after a failed renew pace themselves with the seeded
        // backoff (seed = worker id) instead of hammering every tick.
        let mut backoff = Backoff::new(
            RetryPolicy::for_poll(Duration::from_millis(100)),
            fnv1a64(worker.as_bytes()) ^ 0x48_42, // "HB"
        );
        let mut retry_at: Option<Duration> = None;
        while !stop.load(Ordering::SeqCst) {
            std::thread::sleep(tick);
            let lease = held.lock().expect("held lease poisoned").clone();
            let Some((job, chunk, every)) = lease else { continue };
            let now = clock.now();
            if now.saturating_sub(last) < every || retry_at.is_some_and(|t| now < t) {
                continue;
            }
            if client.is_none() {
                client = transport.connect(&addr).ok().map(Client::over);
            }
            let tally = *work.lock().expect("work tally poisoned");
            let renewed = client
                .as_mut()
                .is_some_and(|c| c.lease_renew(&worker, &job, chunk, Some(tally)).is_ok());
            if renewed {
                backoff.reset();
                retry_at = None;
            } else {
                client = None;
                retry_at = backoff.next_delay(clock.as_ref()).map(|d| now + d);
            }
            last = now;
        }
    })
}

/// Join a running determinant server as a fleet worker over real TCP
/// and serve chunk leases until stopped, idle-exhausted, or
/// budget-bounded (see [`WorkerConfig`]). `stop` makes the loop
/// cooperative: raise it and the worker finishes (and delivers) its
/// in-flight chunk, then exits.
pub fn run_worker(addr: &str, cfg: &WorkerConfig, stop: &AtomicBool) -> Result<WorkerReport> {
    run_worker_with(Arc::new(TcpTransport), addr, cfg, stop, clock::wall())
}

/// [`run_worker`] over an explicit transport and clock — the seam the
/// simulation fabric and transport tests use. Idle and reconnect pacing
/// follow the seeded [`RetryPolicy::for_poll`] schedule derived from
/// `cfg.poll` (seed = worker id, so a fleet's delays are decorrelated
/// but each worker's are replayable), sleeping on the given clock; the
/// heartbeat thread is only spawned on real transports' behalf but is
/// harmless (and idle) under sim, where steps are atomic with respect
/// to virtual time.
pub fn run_worker_with(
    transport: Arc<dyn Transport>,
    addr: &str,
    cfg: &WorkerConfig,
    stop: &AtomicBool,
    clock: Arc<dyn Clock>,
) -> Result<WorkerReport> {
    let mut worker = Worker::connect(Arc::clone(&transport), addr, cfg.clone(), clock.clone())?;
    let heartbeat_stop = Arc::new(AtomicBool::new(false));
    let heartbeat = spawn_heartbeat(
        transport,
        addr.to_string(),
        cfg.id.clone(),
        worker.held_handle(),
        worker.work_handle(),
        Arc::clone(&heartbeat_stop),
        Arc::clone(&clock),
    );
    let policy = RetryPolicy::for_poll(cfg.poll);
    let seed = fnv1a64(cfg.id.as_bytes());
    // Separate schedules: an idle server (no leasable work — the
    // connection is fine) and a dead one (redialing) are different
    // regimes; a completed chunk resets both.
    let mut idle = Backoff::new(policy, seed);
    let mut reconnect = Backoff::new(policy, seed ^ 1);
    let mut run_err: Option<Error> = None;
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match worker.step() {
            Ok(WorkerEvent::Idle) => {
                if cfg.exit_on_idle {
                    break;
                }
                reconnect.reset(); // the server answered — link is up
                idle.sleep(clock.as_ref());
            }
            Ok(WorkerEvent::Disconnected) => {
                idle.reset();
                reconnect.sleep(clock.as_ref());
            }
            Ok(WorkerEvent::JobComplete)
            | Ok(WorkerEvent::Crashed { .. })
            | Ok(WorkerEvent::BudgetExhausted) => break,
            Ok(WorkerEvent::Completed { .. }) | Ok(WorkerEvent::Rejected { .. }) => {
                idle.reset();
                reconnect.reset();
            }
            Err(e) => {
                run_err = Some(e);
                break;
            }
        }
    }
    heartbeat_stop.store(true, Ordering::SeqCst);
    let _ = heartbeat.join();
    let report = worker.finish();
    match run_err {
        Some(e) => Err(e),
        None => Ok(report),
    }
}
