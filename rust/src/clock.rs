//! The virtual-time seam: every timing-dependent component (lease TTLs,
//! worker heartbeats, `JOB WAIT` deadlines, job-id timestamps) reads
//! time through a [`Clock`] instead of `Instant::now()` /
//! `thread::sleep`, so the deterministic simulation fabric
//! ([`crate::testkit::sim`]) can run the identical code under a
//! manually-advanced [`SimClock`] — TTL expiry, heartbeat races and
//! restart windows become replayable functions of a seed instead of
//! wall-clock races.
//!
//! Timestamps are a [`Duration`] since the clock's epoch (process start
//! for [`WallClock`], zero for [`SimClock`]). Components never compare
//! timestamps across clocks; they only do deadline arithmetic on one
//! clock, which is why a plain `Duration` suffices and no `Instant`
//! needs to be forged.

use std::collections::BTreeSet;
use std::fmt;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// A source of monotonic time plus the ability to block on it.
///
/// Implementations must be cheap to `now()` (it sits inside lease-table
/// critical sections) and safe to share across threads.
pub trait Clock: Send + Sync + fmt::Debug {
    /// Monotonic time elapsed since this clock's epoch.
    fn now(&self) -> Duration;

    /// Block the calling thread for `d` of *this clock's* time. Under a
    /// [`SimClock`] this parks the thread until someone advances virtual
    /// time past the deadline.
    fn sleep(&self, d: Duration);

    /// Deadline `ttl` from now (saturating).
    fn deadline(&self, ttl: Duration) -> Duration {
        self.now().saturating_add(ttl)
    }

    /// Has `deadline` passed?
    fn expired(&self, deadline: Duration) -> bool {
        self.now() >= deadline
    }
}

/// The production clock: real monotonic time, real sleeps.
#[derive(Debug)]
pub struct WallClock {
    epoch: Instant,
}

impl WallClock {
    /// A wall clock whose epoch is "now".
    pub fn new() -> Self {
        Self { epoch: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> Duration {
        self.epoch.elapsed()
    }

    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// Shared handle to the production clock.
pub fn wall() -> Arc<dyn Clock> {
    Arc::new(WallClock::new())
}

#[derive(Debug, Default)]
struct SimState {
    now: Duration,
    next_token: u64,
    /// Registered sleeper deadlines, ordered — the wake order contract.
    sleepers: BTreeSet<(Duration, u64)>,
}

/// A manually-advanced virtual clock.
///
/// `now` only moves when a test (or the sim scheduler) calls
/// [`SimClock::advance`] / [`SimClock::advance_to`]. Sleeping threads
/// register a deadline and are woken **in timestamp order**: an advance
/// walks the pending deadlines earliest-first, moves `now` to each one,
/// and waits for that sleeper to actually resume (deregister) before
/// moving further — so two sleepers never observe time out of order,
/// which is what makes multi-threaded sim tests replayable.
///
/// A sleep with no future advance blocks forever by design: virtual
/// time has no background source, so a hung sim test points straight at
/// the missing `advance` instead of flaking.
#[derive(Debug, Default)]
pub struct SimClock {
    state: Mutex<SimState>,
    cv: Condvar,
}

impl SimClock {
    /// A fresh virtual clock at `t = 0`, shareable across threads.
    pub fn new() -> Arc<SimClock> {
        Arc::new(SimClock::default())
    }

    /// Advance virtual time by `d`, waking sleepers in deadline order.
    pub fn advance(&self, d: Duration) {
        let target = {
            let st = self.state.lock().expect("sim clock poisoned");
            st.now.saturating_add(d)
        };
        self.advance_to(target);
    }

    /// Advance virtual time to `target` (no-op if already past it).
    pub fn advance_to(&self, target: Duration) {
        let mut st = self.state.lock().expect("sim clock poisoned");
        loop {
            let next = st.sleepers.iter().next().copied();
            match next {
                Some((deadline, token)) if deadline <= target => {
                    if st.now < deadline {
                        st.now = deadline;
                    }
                    self.cv.notify_all();
                    // Wait for that sleeper to resume and deregister
                    // before time moves on — the in-order-wake contract.
                    while st.sleepers.contains(&(deadline, token)) {
                        st = self.cv.wait(st).expect("sim clock poisoned");
                    }
                }
                _ => {
                    if st.now < target {
                        st.now = target;
                    }
                    self.cv.notify_all();
                    return;
                }
            }
        }
    }
}

impl Clock for SimClock {
    fn now(&self) -> Duration {
        self.state.lock().expect("sim clock poisoned").now
    }

    fn sleep(&self, d: Duration) {
        if d.is_zero() {
            return;
        }
        let mut st = self.state.lock().expect("sim clock poisoned");
        let deadline = st.now.saturating_add(d);
        let token = st.next_token;
        st.next_token += 1;
        st.sleepers.insert((deadline, token));
        while st.now < deadline {
            st = self.cv.wait(st).expect("sim clock poisoned");
        }
        st.sleepers.remove(&(deadline, token));
        // Unblock an advancer waiting for this sleeper to resume.
        self.cv.notify_all();
    }
}

/// A broadcast wakeup: an epoch counter plus a condvar. Waiters record
/// the epoch they have seen and block until it moves (or a real-time
/// backstop elapses) — the condvar-with-deadline primitive that replaces
/// fixed-interval polling in [`crate::jobs::JobManager::wait`].
#[derive(Debug, Default)]
pub struct Notify {
    epoch: Mutex<u64>,
    cv: Condvar,
}

impl Notify {
    /// A fresh notifier at epoch 0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Bump the epoch and wake all waiters.
    pub fn notify(&self) {
        *self.epoch.lock().expect("notify poisoned") += 1;
        self.cv.notify_all();
    }

    /// The current epoch (capture *before* re-checking the condition you
    /// wait on, so a notify between check and wait is never lost).
    pub fn epoch(&self) -> u64 {
        *self.epoch.lock().expect("notify poisoned")
    }

    /// Block until the epoch moves past `seen` or `backstop` (real time)
    /// elapses. Returns the epoch observed on wakeup.
    pub fn wait_past(&self, seen: u64, backstop: Duration) -> u64 {
        let deadline = Instant::now() + backstop;
        let mut g = self.epoch.lock().expect("notify poisoned");
        while *g <= seen {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (ng, _) = self
                .cv
                .wait_timeout(g, deadline - now)
                .expect("notify poisoned");
            g = ng;
        }
        *g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn wall_clock_moves_and_sleeps() {
        let c = WallClock::new();
        let t0 = c.now();
        c.sleep(Duration::from_millis(2));
        assert!(c.now() > t0);
        let d = c.deadline(Duration::from_secs(3600));
        assert!(!c.expired(d));
    }

    #[test]
    fn sim_clock_only_moves_on_advance() {
        let c = SimClock::new();
        assert_eq!(c.now(), Duration::ZERO);
        c.advance(Duration::from_millis(250));
        assert_eq!(c.now(), Duration::from_millis(250));
        c.advance_to(Duration::from_millis(100)); // backwards is a no-op
        assert_eq!(c.now(), Duration::from_millis(250));
        let d = c.deadline(Duration::from_millis(50));
        assert!(!c.expired(d));
        c.advance(Duration::from_millis(50));
        assert!(c.expired(d));
    }

    #[test]
    fn sim_sleepers_wake_in_timestamp_order() {
        let c = SimClock::new();
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        // Spawn sleepers with distinct deadlines, registration order
        // scrambled relative to deadline order.
        for (label, ms) in [("c", 30u64), ("a", 10), ("b", 20)] {
            let c = Arc::clone(&c);
            let order = Arc::clone(&order);
            handles.push(std::thread::spawn(move || {
                c.sleep(Duration::from_millis(ms));
                order.lock().unwrap().push(label);
            }));
        }
        // Let all three register before advancing.
        while c.state.lock().unwrap().sleepers.len() < 3 {
            std::thread::yield_now();
        }
        // Step time deadline by deadline: after each advance only the
        // newly-due sleeper can have woken, so the recorded order is
        // the deadline order by construction of the clock.
        let mut want = Vec::new();
        for label in ["a", "b", "c"] {
            c.advance(Duration::from_millis(10));
            want.push(label);
            while order.lock().unwrap().len() < want.len() {
                std::thread::yield_now();
            }
            assert_eq!(*order.lock().unwrap(), want);
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn sim_sleep_past_target_stays_asleep() {
        let c = SimClock::new();
        let c2 = Arc::clone(&c);
        let done = Arc::new(AtomicUsize::new(0));
        let done2 = Arc::clone(&done);
        let h = std::thread::spawn(move || {
            c2.sleep(Duration::from_millis(100));
            done2.store(1, Ordering::SeqCst);
        });
        while c.state.lock().unwrap().sleepers.is_empty() {
            std::thread::yield_now();
        }
        c.advance(Duration::from_millis(50));
        assert_eq!(done.load(Ordering::SeqCst), 0, "deadline not reached");
        c.advance(Duration::from_millis(50));
        h.join().unwrap();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn notify_wakes_waiter_before_backstop() {
        let n = Arc::new(Notify::new());
        let seen = n.epoch();
        let n2 = Arc::clone(&n);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            n2.notify();
        });
        let t0 = Instant::now();
        let after = n.wait_past(seen, Duration::from_secs(30));
        assert!(after > seen);
        assert!(t0.elapsed() < Duration::from_secs(10));
        h.join().unwrap();
    }

    #[test]
    fn notify_backstop_elapses_without_signal() {
        let n = Notify::new();
        let seen = n.epoch();
        let after = n.wait_past(seen, Duration::from_millis(5));
        assert_eq!(after, seen);
    }
}
