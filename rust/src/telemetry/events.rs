//! The structured event layer: a bounded ring of `(timestamp, kind,
//! detail)` events read through the [`Clock`] seam.
//!
//! Under the deterministic simulation fabric the clock is a
//! [`crate::clock::SimClock`], so event timestamps are **virtual** —
//! two replays of one seed produce byte-identical event streams. In
//! production the clock is the wall and the ring is a cheap flight
//! recorder (`raddet serve` keeps the last few hundred protocol-level
//! events for post-mortems).
//!
//! Events render to JSONL (one `{"t_ms":…,"kind":…,"detail":…}` object
//! per line) with the same dependency-free [`json_escape`] the
//! `raddet sim --trace-json` exporter uses.

use crate::clock::Clock;
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// One recorded event.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Event {
    /// Timestamp on the log's clock (virtual under sim).
    pub at: Duration,
    /// Short machine-readable kind tag (`grant`, `complete`, …).
    pub kind: String,
    /// Free-form human detail.
    pub detail: String,
}

/// A bounded ring of [`Event`]s stamped through a shared [`Clock`].
#[derive(Debug)]
pub struct EventLog {
    clock: Arc<dyn Clock>,
    cap: usize,
    events: Mutex<VecDeque<Event>>,
}

impl EventLog {
    /// A fresh log holding at most `cap` events (oldest evicted first).
    pub fn new(clock: Arc<dyn Clock>, cap: usize) -> EventLog {
        EventLog {
            clock,
            cap: cap.max(1),
            events: Mutex::new(VecDeque::new()),
        }
    }

    /// Record an event stamped with the clock's current time.
    pub fn record(&self, kind: &str, detail: impl Into<String>) {
        let event = Event {
            at: self.clock.now(),
            kind: kind.to_string(),
            detail: detail.into(),
        };
        let mut events = self.events.lock().expect("event log poisoned");
        if events.len() == self.cap {
            events.pop_front();
        }
        events.push_back(event);
    }

    /// The retained events, oldest first.
    pub fn events(&self) -> Vec<Event> {
        self.events
            .lock()
            .expect("event log poisoned")
            .iter()
            .cloned()
            .collect()
    }

    /// Render the retained events as JSONL.
    pub fn render_jsonl(&self) -> String {
        let mut out = String::new();
        for e in self.events() {
            out.push_str(&format!(
                "{{\"t_ms\":{},\"kind\":\"{}\",\"detail\":\"{}\"}}\n",
                e.at.as_millis(),
                json_escape(&e.kind),
                json_escape(&e.detail)
            ));
        }
        out
    }
}

/// Escape `s` for embedding in a JSON string literal (quotes,
/// backslashes, control characters).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::SimClock;

    #[test]
    fn events_carry_virtual_timestamps_under_sim() {
        let clock = SimClock::new();
        let log = EventLog::new(clock.clone(), 16);
        log.record("grant", "w1 takes job0#0");
        clock.advance(Duration::from_millis(250));
        log.record("complete", "w1 lands job0#0");
        let events = log.events();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].at, Duration::ZERO);
        assert_eq!(events[1].at, Duration::from_millis(250));
        assert_eq!(
            log.render_jsonl(),
            "{\"t_ms\":0,\"kind\":\"grant\",\"detail\":\"w1 takes job0#0\"}\n\
             {\"t_ms\":250,\"kind\":\"complete\",\"detail\":\"w1 lands job0#0\"}\n"
        );
    }

    #[test]
    fn ring_evicts_oldest() {
        let log = EventLog::new(SimClock::new(), 2);
        log.record("a", "");
        log.record("b", "");
        log.record("c", "");
        let kinds: Vec<String> = log.events().into_iter().map(|e| e.kind).collect();
        assert_eq!(kinds, ["b", "c"]);
    }

    #[test]
    fn json_escaping_covers_the_hostile_cases() {
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("line\nbreak\ttab"), "line\\nbreak\\ttab");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }
}
