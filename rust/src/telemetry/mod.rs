//! Fleet telemetry — the observability layer the self-managing fleet
//! is built on.
//!
//! The paper's O(n²) bound assumes perfectly balanced parallel sweeps
//! over the `C(n,m)` term space; in practice a fleet is only as fast as
//! its slowest worker. Before the lease table can *react* to a
//! straggler (adaptive chunking, speculative re-lease — see
//! ROADMAP.md), it has to *see* one. This module is the eyes:
//!
//! * [`Registry`] — a lock-cheap metrics registry of monotonic
//!   [`Counter`]s, [`Gauge`]s and fixed-bucket [`Histogram`]s.
//!   Handles are `Arc`'d atomics: registration takes a mutex once,
//!   increments are a single relaxed atomic op, so counters can sit on
//!   hot paths (per-request, per-append). [`Registry::snapshot`]
//!   renders every metric into one canonical, name-ordered `key=value`
//!   text encoding — the body of the wire `METRICS` verb, pinned by a
//!   golden test.
//! * [`EventLog`] — a bounded ring of structured events stamped through
//!   the [`crate::clock::Clock`] seam, so events carry **virtual**
//!   timestamps under the deterministic simulation fabric
//!   ([`crate::testkit::sim`]) and wall timestamps in production. The
//!   same rule makes every latency measurement in the crate
//!   deterministic under sim: nothing advances a
//!   [`crate::clock::SimClock`] while a measured operation runs, so
//!   simulated latencies are exact functions of the scenario script,
//!   never of host scheduling.
//!
//! Ownership: registries are **explicit instances** (one per
//! [`crate::service::ServiceCore`]), never process globals — tests and
//! sim worlds each get an isolated registry, which is what lets the
//! seeded-replay suites assert snapshot equality across runs.
//!
//! What is counted where:
//!
//! * service core — per-verb request counters, error replies, rejected
//!   frames (`service_*`);
//! * lease table — grants, renews, completes, duplicate completes,
//!   expiries, abandons (`fleet_*`), plus per-job per-worker rows
//!   (EWMA throughput, held/completed/abandoned/expired/duplicate
//!   counts) surfaced by the `METRICS JOB` verb;
//! * jobs/storage — journal append/fsync latency histograms and error
//!   counters via [`crate::jobs::MeteredFs`] (`fs_*`), fault-injection
//!   tallies via [`crate::jobs::FaultFs::tallies`];
//! * engine — blocks vs fallback blocks per scalar kind, captured from
//!   each background run's [`crate::coordinator::JobMetrics`]
//!   (`engine_*`).

pub mod events;
pub mod registry;

pub use events::{json_escape, Event, EventLog};
pub use registry::{Counter, Gauge, Histogram, Registry, Snapshot, LATENCY_BUCKETS_US};
