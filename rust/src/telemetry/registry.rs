//! The metrics registry: monotonic counters, gauges and fixed-bucket
//! histograms behind `Arc`'d atomic handles, snapshot-able to one
//! canonical text encoding.
//!
//! Design constraints, in order:
//!
//! 1. **Lock-cheap.** A handle, once registered, is an `Arc<AtomicU64>`
//!    (or a few of them): incrementing from a hot path is one relaxed
//!    atomic add, no mutex. The registry's mutex is taken only at
//!    registration and snapshot time.
//! 2. **Canonical encoding.** [`Registry::snapshot`] emits
//!    `name=value` pairs ordered by metric name (histograms expand
//!    into `_count` / `_le_<bound>` / `_le_inf` / `_sum` series in
//!    ascending-bound order), so two snapshots of equal state encode
//!    to equal bytes — the property the wire `METRICS` verb and the
//!    seeded-replay tests lean on.
//! 3. **Dependency-free.** Plain std; values are integers only, so the
//!    encoding never meets float formatting.
//!
//! Metric names are lowercase `[a-z0-9_]+` — they travel on a
//! space-separated wire line, so the charset is locked down at
//! registration.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Default latency bucket upper bounds, in microseconds — spans one
/// journal fsync (~100µs–10ms) through a slow chunk (~100ms+).
pub const LATENCY_BUCKETS_US: [u64; 8] =
    [100, 500, 1_000, 5_000, 10_000, 50_000, 100_000, 500_000];

/// Is `name` a valid metric name (lowercase `[a-z0-9_]+`)?
fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name
            .bytes()
            .all(|b| b.is_ascii_lowercase() || b.is_ascii_digit() || b == b'_')
}

/// A monotonic counter. Cloning shares the underlying cell.
#[derive(Clone, Debug, Default)]
pub struct Counter {
    value: Arc<AtomicU64>,
}

impl Counter {
    /// Add one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// A settable signed gauge. Cloning shares the underlying cell.
#[derive(Clone, Debug, Default)]
pub struct Gauge {
    value: Arc<AtomicI64>,
}

impl Gauge {
    /// Set the gauge to `v`.
    pub fn set(&self, v: i64) {
        self.value.store(v, Ordering::Relaxed);
    }

    /// Add `d` (may be negative).
    pub fn add(&self, d: i64) {
        self.value.fetch_add(d, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.value.load(Ordering::Relaxed)
    }
}

#[derive(Debug)]
struct HistogramInner {
    /// Ascending bucket upper bounds (inclusive). One extra implicit
    /// `+inf` bucket catches the overflow.
    bounds: Vec<u64>,
    /// Cumulative-style per-bucket hit counts, one per bound plus the
    /// overflow slot (stored non-cumulative; the snapshot accumulates).
    counts: Vec<AtomicU64>,
    sum: AtomicU64,
    count: AtomicU64,
}

/// A fixed-bucket histogram of `u64` samples (latencies in µs,
/// throughputs in milli-terms/sec). Cloning shares the buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    inner: Arc<HistogramInner>,
}

impl Histogram {
    fn new(bounds: &[u64]) -> Histogram {
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly ascending"
        );
        Histogram {
            inner: Arc::new(HistogramInner {
                bounds: bounds.to_vec(),
                counts: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
                sum: AtomicU64::new(0),
                count: AtomicU64::new(0),
            }),
        }
    }

    /// Record one sample.
    pub fn record(&self, value: u64) {
        let idx = self
            .inner
            .bounds
            .iter()
            .position(|&b| value <= b)
            .unwrap_or(self.inner.bounds.len());
        self.inner.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.inner.sum.fetch_add(value, Ordering::Relaxed);
        self.inner.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.inner.count.load(Ordering::Relaxed)
    }

    /// Sum of all samples.
    pub fn sum(&self) -> u64 {
        self.inner.sum.load(Ordering::Relaxed)
    }

    /// Expand into the snapshot series for metric `name`, buckets
    /// cumulative (`_le_*` counts samples at or below the bound).
    fn expand(&self, name: &str, out: &mut Vec<(String, String)>) {
        out.push((format!("{name}_count"), self.count().to_string()));
        let mut cum = 0u64;
        for (i, bound) in self.inner.bounds.iter().enumerate() {
            cum += self.inner.counts[i].load(Ordering::Relaxed);
            out.push((format!("{name}_le_{bound}"), cum.to_string()));
        }
        cum += self.inner.counts[self.inner.bounds.len()].load(Ordering::Relaxed);
        out.push((format!("{name}_le_inf"), cum.to_string()));
        out.push((format!("{name}_sum"), self.sum().to_string()));
    }
}

#[derive(Clone, Debug)]
enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

/// The registry: a name → metric map handing out shared atomic handles.
///
/// One registry per server core (see the module docs in
/// [`crate::telemetry`]) — never a process global, so tests and sim
/// worlds stay isolated.
#[derive(Debug, Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    /// A fresh, empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-register the counter `name`.
    ///
    /// # Panics
    /// If `name` is not lowercase `[a-z0-9_]+`, or is already
    /// registered as a different metric kind — both are programming
    /// errors, not runtime conditions.
    pub fn counter(&self, name: &str) -> Counter {
        assert!(valid_name(name), "invalid metric name {name:?}");
        let mut metrics = self.metrics.lock().expect("metric registry poisoned");
        match metrics
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Counter::default()))
        {
            Metric::Counter(c) => c.clone(),
            other => panic!("metric {name:?} already registered as {other:?}"),
        }
    }

    /// Get-or-register the gauge `name` (same rules as
    /// [`Registry::counter`]).
    pub fn gauge(&self, name: &str) -> Gauge {
        assert!(valid_name(name), "invalid metric name {name:?}");
        let mut metrics = self.metrics.lock().expect("metric registry poisoned");
        match metrics
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Gauge::default()))
        {
            Metric::Gauge(g) => g.clone(),
            other => panic!("metric {name:?} already registered as {other:?}"),
        }
    }

    /// Get-or-register the histogram `name` with the given ascending
    /// bucket bounds (ignored if the name is already registered — the
    /// first registration wins the geometry).
    pub fn histogram(&self, name: &str, bounds: &[u64]) -> Histogram {
        assert!(valid_name(name), "invalid metric name {name:?}");
        let mut metrics = self.metrics.lock().expect("metric registry poisoned");
        match metrics
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Histogram::new(bounds)))
        {
            Metric::Histogram(h) => h.clone(),
            other => panic!("metric {name:?} already registered as {other:?}"),
        }
    }

    /// Snapshot every registered metric into the canonical encoding.
    pub fn snapshot(&self) -> Snapshot {
        let metrics = self.metrics.lock().expect("metric registry poisoned");
        let mut pairs = Vec::with_capacity(metrics.len());
        for (name, metric) in metrics.iter() {
            match metric {
                Metric::Counter(c) => pairs.push((name.clone(), c.get().to_string())),
                Metric::Gauge(g) => pairs.push((name.clone(), g.get().to_string())),
                Metric::Histogram(h) => h.expand(name, &mut pairs),
            }
        }
        Snapshot { pairs }
    }
}

/// A point-in-time rendering of a [`Registry`]: name-ordered
/// `(name, integer-value)` pairs (histogram series expand under their
/// metric's name, buckets ascending).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Snapshot {
    pairs: Vec<(String, String)>,
}

impl Snapshot {
    /// Build a snapshot directly from pairs (the client-side decode of
    /// a wire `OK METRICS` reply).
    pub fn from_pairs(pairs: Vec<(String, String)>) -> Snapshot {
        Snapshot { pairs }
    }

    /// The ordered pairs.
    pub fn pairs(&self) -> &[(String, String)] {
        &self.pairs
    }

    /// Value of `name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.pairs
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }

    /// The canonical single-line text encoding: `name=value` pairs
    /// joined by single spaces, in snapshot order.
    pub fn encode(&self) -> String {
        let mut out = String::new();
        for (i, (name, value)) in self.pairs.iter().enumerate() {
            if i > 0 {
                out.push(' ');
            }
            out.push_str(name);
            out.push('=');
            out.push_str(value);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_share_state_across_clones() {
        let reg = Registry::new();
        let c = reg.counter("requests_total");
        c.inc();
        reg.counter("requests_total").add(4);
        assert_eq!(c.get(), 5, "same name ⇒ same cell");
        let g = reg.gauge("open_jobs");
        g.set(3);
        g.add(-1);
        assert_eq!(reg.gauge("open_jobs").get(), 2);
    }

    #[test]
    fn histogram_buckets_accumulate() {
        let reg = Registry::new();
        let h = reg.histogram("lat_us", &[10, 100]);
        for v in [5, 7, 50, 5000] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum(), 5062);
        let snap = reg.snapshot();
        assert_eq!(snap.get("lat_us_count"), Some("4"));
        assert_eq!(snap.get("lat_us_le_10"), Some("2"));
        assert_eq!(snap.get("lat_us_le_100"), Some("3"));
        assert_eq!(snap.get("lat_us_le_inf"), Some("4"));
        assert_eq!(snap.get("lat_us_sum"), Some("5062"));
    }

    /// The golden test pinning the canonical METRICS text encoding —
    /// if this changes, docs/PROTOCOL.md and every consumer of the
    /// `METRICS` verb change with it.
    #[test]
    fn snapshot_encoding_is_canonical() {
        let reg = Registry::new();
        reg.counter("zz_last").add(7);
        reg.gauge("balance").set(-2);
        let h = reg.histogram("append_us", &[100, 500]);
        h.record(40);
        h.record(400);
        reg.counter("aa_first").inc();
        let got = reg.snapshot().encode();
        assert_eq!(
            got,
            "aa_first=1 append_us_count=2 append_us_le_100=1 append_us_le_500=2 \
             append_us_le_inf=2 append_us_sum=440 balance=-2 zz_last=7"
        );
        // Equal state ⇒ equal bytes, independent of registration order.
        let reg2 = Registry::new();
        reg2.gauge("balance").set(-2);
        reg2.counter("aa_first").inc();
        let h2 = reg2.histogram("append_us", &[100, 500]);
        h2.record(400);
        h2.record(40);
        reg2.counter("zz_last").add(7);
        assert_eq!(reg2.snapshot().encode(), got);
    }

    #[test]
    fn empty_registry_encodes_empty() {
        assert_eq!(Registry::new().snapshot().encode(), "");
        assert_eq!(Registry::new().snapshot().pairs().len(), 0);
    }

    #[test]
    #[should_panic(expected = "invalid metric name")]
    fn names_with_spaces_are_rejected() {
        Registry::new().counter("has space");
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn kind_clash_panics() {
        let reg = Registry::new();
        reg.counter("x");
        reg.gauge("x");
    }
}
