//! Bench: the paper's core complexity claim — unranking one dictionary
//! element costs O(m·(n−m)), independent of C(n,m).
//!
//! Sweeps (m, n−m), measures ns per unrank at random ranks, and prints
//! the fitted cost per unit of m·(n−m), which must stay flat while
//! C(n,m) grows by orders of magnitude. Also compares the per-element
//! cost of the §5 chunk walk (one unrank + successors) against
//! unranking every element — the reason granularity chunks exist.

use raddet::bench::{bench, fmt_time, BenchConfig, Table};
use raddet::combin::{combination_count, unrank_into, CombinationStream, PascalTable};
use raddet::testkit::TestRng;

fn main() {
    let cfg = BenchConfig::default();
    println!("## bench_unrank — O(m(n−m)) per element\n");

    let mut table = Table::new(&[
        "n", "m", "m(n−m)", "C(n,m)", "ns/unrank", "ns per m(n−m)",
    ]);
    let sweep: &[(u64, u64)] = &[
        (16, 8),
        (24, 12),
        (32, 8),
        (32, 16),
        (48, 24),
        (64, 16),
        (64, 32),
        (96, 48),
        (128, 16),
        (120, 60), // C(120,60) ≈ 1e35 — near the u128 ceiling
    ];
    for &(n, m) in sweep {
        let total = combination_count(n, m).unwrap();
        let ptable = PascalTable::new(n, m).unwrap();
        let mut rng = TestRng::from_seed(n * 1000 + m);
        // Pre-draw ranks so RNG cost stays out of the loop.
        let ranks: Vec<u128> = (0..256).map(|_| rng.u128_below(total)).collect();
        let mut buf = vec![0u32; m as usize];
        let mut i = 0;
        let stats = bench(&cfg, || {
            i = (i + 1) % ranks.len();
            unrank_into(&ptable, ranks[i], &mut buf).unwrap();
            buf[0]
        });
        let ns = stats.median * 1e9;
        let work = (m * (n - m)) as f64;
        table.row(&[
            n.to_string(),
            m.to_string(),
            format!("{}", m * (n - m)),
            format!("{total:.2e}"),
            format!("{ns:.0}"),
            format!("{:.2}", ns / work),
        ]);
    }
    print!("{}", table.render());
    println!("\n(the last column flat ⇒ O(m(n−m)) confirmed; C(n,m) spans ~20 orders)\n");

    // Chunk-walk amortization: per-element cost of stream vs unrank-all.
    println!("## §5 chunk walk: successor amortization\n");
    let mut t2 = Table::new(&["n", "m", "chunk", "ns/elem (stream)", "ns/elem (unrank-all)", "ratio"]);
    for &(n, m, chunk) in &[(32u64, 8u64, 4096u128), (64, 16, 4096), (96, 24, 4096)] {
        let ptable = PascalTable::new(n, m).unwrap();
        let total = combination_count(n, m).unwrap();
        let start = total / 3;

        let stream_stats = bench(&BenchConfig { samples: 10, ..cfg }, || {
            let mut s = CombinationStream::new(&ptable, start, chunk).unwrap();
            let mut acc = 0u32;
            while let Some(c) = s.next_ref() {
                acc ^= c[0];
            }
            acc
        });
        let mut buf = vec![0u32; m as usize];
        let unrank_stats = bench(&BenchConfig { samples: 10, ..cfg }, || {
            let mut acc = 0u32;
            for q in start..start + chunk {
                unrank_into(&ptable, q, &mut buf).unwrap();
                acc ^= buf[0];
            }
            acc
        });
        let per_stream = stream_stats.median / chunk as f64;
        let per_unrank = unrank_stats.median / chunk as f64;
        t2.row(&[
            n.to_string(),
            m.to_string(),
            chunk.to_string(),
            format!("{:.1}", per_stream * 1e9),
            format!("{:.1}", per_unrank * 1e9),
            format!("{:.1}×", per_unrank / per_stream),
        ]);
    }
    print!("{}", t2.render());
    println!("\ntable-build cost (paid once per job):");
    for &(n, m) in &[(64u64, 32u64), (128, 64)] {
        let s = bench(&cfg, || PascalTable::new(n, m).unwrap().at(0, 0));
        println!("  PascalTable::new({n},{m}): {}", fmt_time(s.median));
    }
}
