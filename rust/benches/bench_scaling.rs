//! Bench: strong scaling of the §5 parallel decomposition — wall-clock
//! speedup/efficiency vs worker count, static vs work-stealing.
//!
//! TESTBED NOTE: this container exposes **1 CPU core**, so thread-level
//! speedup is hardware-gated at ~1× (threads time-slice one core). The
//! mechanical claims are still validated here — exact work cover,
//! worker-count-independent results, balance — while the *complexity*
//! side of the paper's parallel claim is reproduced on the PRAM
//! simulator (bench_pram), per DESIGN.md §2 substitution 1.

use raddet::bench::stats::{json_f64, json_object, Stats};
use raddet::bench::{bench, fmt_time, BenchConfig, Table};
use raddet::combin::combination_count;
use raddet::coordinator::{Coordinator, CoordinatorConfig, EngineKind, Schedule};
use raddet::matrix::gen;
use raddet::scalar::ScalarKind;
use raddet::testkit::TestRng;

fn run(workers: usize, schedule: Schedule, a: &raddet::matrix::MatF64) -> (f64, f64, f64) {
    let coord = Coordinator::new(CoordinatorConfig {
        workers,
        engine: EngineKind::Cpu,
        schedule,
        batch: 256,
        ..Default::default()
    })
    .unwrap();
    // Best-of-3 to damp scheduler noise.
    let mut best = f64::MAX;
    let mut det = 0.0;
    let mut balance = 1.0;
    for _ in 0..3 {
        let out = coord.radic_det(a).unwrap();
        let secs = out.metrics.elapsed.as_secs_f64();
        if secs < best {
            best = secs;
            det = out.det;
            balance = out.metrics.balance();
        }
    }
    (best, det, balance)
}

fn main() {
    let (m, n) = (6usize, 24usize);
    let total = combination_count(n as u64, m as u64).unwrap();
    println!(
        "## strong scaling — {m}×{n} ({total} terms), cpu-lu engine\n"
    );
    println!(
        "(testbed: {} hardware core(s) — see note in the bench source)\n",
        std::thread::available_parallelism().map_or(1, |p| p.get())
    );
    let a = gen::uniform(&mut TestRng::from_seed(99), m, n, -1.0, 1.0);

    let (t1, base_det, _) = run(1, Schedule::Static, &a);
    let mut table = Table::new(&[
        "workers", "schedule", "time", "speedup", "efficiency", "balance", "agree",
    ]);
    for &w in &[1usize, 2, 4, 8] {
        for (schedule, name) in [
            (Schedule::Static, "static"),
            (Schedule::WorkStealing { grain: 2048 }, "steal"),
        ] {
            let (t, det, balance) = run(w, schedule, &a);
            let agree = (det - base_det).abs() < 1e-9 * base_det.abs().max(1.0);
            assert!(agree, "worker count changed the result");
            table.row(&[
                w.to_string(),
                name.into(),
                fmt_time(t),
                format!("{:.2}×", t1 / t),
                format!("{:.0}%", 100.0 * t1 / t / w as f64),
                format!("{balance:.2}"),
                "✓".into(),
            ]);
        }
    }
    print!("{}", table.render());

    println!("\n## granularity ablation (work-stealing grain, 4 workers)\n");
    let mut t2 = Table::new(&["grain", "time", "chunks claimed"]);
    for grain in [64u64, 512, 4096, 32768] {
        let coord = Coordinator::new(CoordinatorConfig {
            workers: 4,
            engine: EngineKind::Cpu,
            schedule: Schedule::WorkStealing { grain },
            batch: 256,
            ..Default::default()
        })
        .unwrap();
        let out = coord.radic_det(&a).unwrap();
        t2.row(&[
            grain.to_string(),
            fmt_time(out.metrics.elapsed.as_secs_f64()),
            out.metrics.total().chunks.to_string(),
        ]);
    }
    print!("{}", t2.render());

    scaling_by_scalar();
}

/// Strong scaling per scalar of the tower — the same sweep (integer
/// matrix, cpu-lu + prefix engines) in f64, checked i128 and BigInt,
/// across worker counts. Emits the `BENCH_PR5.json` trajectory
/// datapoint via `bench::stats` (path from `RADDET_BENCH_PR5`,
/// default `BENCH_PR5.json`).
fn scaling_by_scalar() {
    let cfg = BenchConfig::slow();
    let (m, n) = (5usize, 18usize);
    let terms = combination_count(n as u64, m as u64).unwrap();
    let ai = gen::integer(&mut TestRng::from_seed(77), m, n, -60, 60);
    let af = ai.map(|x| x as f64);

    println!("\n## strong scaling by scalar — {m}×{n} ({terms} terms), prefix engine\n");
    let mut table = Table::new(&["workers", "scalar", "time", "Mterms/s", "vs f64"]);
    let mut json_rows: Vec<String> = Vec::new();
    for &w in &[1usize, 2, 4] {
        let coord = Coordinator::new(CoordinatorConfig {
            workers: w,
            engine: EngineKind::Prefix,
            schedule: Schedule::Static,
            ..Default::default()
        })
        .unwrap();
        let mut base = None;
        for scalar in [ScalarKind::F64, ScalarKind::I128, ScalarKind::Big] {
            let stats: Stats = match scalar {
                ScalarKind::F64 => bench(&cfg, || coord.radic_det(&af).unwrap().det),
                ScalarKind::I128 => {
                    bench(&cfg, || coord.radic_det_exact(&ai).unwrap())
                }
                ScalarKind::Big => bench(&cfg, || coord.radic_det_big(&ai).unwrap()),
            };
            let base_median = *base.get_or_insert(stats.median);
            table.row(&[
                w.to_string(),
                scalar.as_str().into(),
                fmt_time(stats.median),
                format!("{:.2}", terms as f64 / stats.median / 1e6),
                format!("{:.2}×", stats.median / base_median),
            ]);
            json_rows.push(json_object(&[
                ("bench", "\"scaling_by_scalar\"".into()),
                ("m", m.to_string()),
                ("n", n.to_string()),
                ("workers", w.to_string()),
                ("scalar", format!("\"{scalar}\"")),
                ("terms", terms.to_string()),
                ("stats", stats.to_json()),
                ("mterms_per_s", json_f64(terms as f64 / stats.median / 1e6)),
            ]));
        }
    }
    print!("{}", table.render());

    let json = format!("[\n  {}\n]\n", json_rows.join(",\n  "));
    let path =
        std::env::var("RADDET_BENCH_PR5").unwrap_or_else(|_| "BENCH_PR5.json".to_string());
    std::fs::write(&path, &json).expect("write BENCH_PR5.json");
    println!("\n(scalar scaling JSON written to {path})");
}
