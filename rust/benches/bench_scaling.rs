//! Bench: strong scaling of the §5 parallel decomposition — wall-clock
//! speedup/efficiency vs worker count, static vs work-stealing.
//!
//! TESTBED NOTE: this container exposes **1 CPU core**, so thread-level
//! speedup is hardware-gated at ~1× (threads time-slice one core). The
//! mechanical claims are still validated here — exact work cover,
//! worker-count-independent results, balance — while the *complexity*
//! side of the paper's parallel claim is reproduced on the PRAM
//! simulator (bench_pram), per DESIGN.md §2 substitution 1.

use raddet::bench::{fmt_time, Table};
use raddet::combin::combination_count;
use raddet::coordinator::{Coordinator, CoordinatorConfig, EngineKind, Schedule};
use raddet::matrix::gen;
use raddet::testkit::TestRng;

fn run(workers: usize, schedule: Schedule, a: &raddet::matrix::MatF64) -> (f64, f64, f64) {
    let coord = Coordinator::new(CoordinatorConfig {
        workers,
        engine: EngineKind::Cpu,
        schedule,
        batch: 256,
        ..Default::default()
    })
    .unwrap();
    // Best-of-3 to damp scheduler noise.
    let mut best = f64::MAX;
    let mut det = 0.0;
    let mut balance = 1.0;
    for _ in 0..3 {
        let out = coord.radic_det(a).unwrap();
        let secs = out.metrics.elapsed.as_secs_f64();
        if secs < best {
            best = secs;
            det = out.det;
            balance = out.metrics.balance();
        }
    }
    (best, det, balance)
}

fn main() {
    let (m, n) = (6usize, 24usize);
    let total = combination_count(n as u64, m as u64).unwrap();
    println!(
        "## strong scaling — {m}×{n} ({total} terms), cpu-lu engine\n"
    );
    println!(
        "(testbed: {} hardware core(s) — see note in the bench source)\n",
        std::thread::available_parallelism().map_or(1, |p| p.get())
    );
    let a = gen::uniform(&mut TestRng::from_seed(99), m, n, -1.0, 1.0);

    let (t1, base_det, _) = run(1, Schedule::Static, &a);
    let mut table = Table::new(&[
        "workers", "schedule", "time", "speedup", "efficiency", "balance", "agree",
    ]);
    for &w in &[1usize, 2, 4, 8] {
        for (schedule, name) in [
            (Schedule::Static, "static"),
            (Schedule::WorkStealing { grain: 2048 }, "steal"),
        ] {
            let (t, det, balance) = run(w, schedule, &a);
            let agree = (det - base_det).abs() < 1e-9 * base_det.abs().max(1.0);
            assert!(agree, "worker count changed the result");
            table.row(&[
                w.to_string(),
                name.into(),
                fmt_time(t),
                format!("{:.2}×", t1 / t),
                format!("{:.0}%", 100.0 * t1 / t / w as f64),
                format!("{balance:.2}"),
                "✓".into(),
            ]);
        }
    }
    print!("{}", table.render());

    println!("\n## granularity ablation (work-stealing grain, 4 workers)\n");
    let mut t2 = Table::new(&["grain", "time", "chunks claimed"]);
    for grain in [64u64, 512, 4096, 32768] {
        let coord = Coordinator::new(CoordinatorConfig {
            workers: 4,
            engine: EngineKind::Cpu,
            schedule: Schedule::WorkStealing { grain },
            batch: 256,
            ..Default::default()
        })
        .unwrap();
        let out = coord.radic_det(&a).unwrap();
        t2.row(&[
            grain.to_string(),
            fmt_time(out.metrics.elapsed.as_secs_f64()),
            out.metrics.total().chunks.to_string(),
        ]);
    }
    print!("{}", t2.render());
}
