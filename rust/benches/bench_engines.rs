//! Bench: determinant engines head-to-head — per-batch latency and
//! terms/second for the pure-rust LU engine vs the AOT JAX/Pallas
//! graph on PJRT, across the shipped m-buckets, plus the inner
//! square-det algorithms (LU vs Laplace vs Bareiss) for context.
//!
//! Note on expectations: the Pallas kernel was lowered with
//! `interpret=True` (the CPU PJRT plugin cannot run Mosaic custom
//! calls), so the XLA numbers here measure *graph dispatch + interpret
//! overhead*, not TPU performance — the structural (VMEM/roofline)
//! analysis lives in DESIGN.md §Perf.

use raddet::bench::{bench, fmt_time, BenchConfig, Table};
use raddet::coordinator::batcher::BatchBuilder;
use raddet::coordinator::engine::{CpuEngine, DetEngine};
use raddet::linalg::{det_bareiss, det_laplace, det_lu};
use raddet::matrix::gen;
use raddet::runtime::{resolve_artifact_dir, Dtype, Manifest, XlaSession};
use raddet::testkit::TestRng;

fn main() {
    let cfg = BenchConfig { samples: 12, ..Default::default() };

    println!("## inner square-determinant algorithms (per det, m×m)\n");
    let mut t0 = Table::new(&["m", "LU", "Laplace", "Bareiss(exact)"]);
    let mut rng = TestRng::from_seed(1);
    for m in [2usize, 4, 6, 8] {
        let a = gen::uniform(&mut rng, m, m, -1.0, 1.0);
        let ai = gen::integer(&mut rng, m, m, -9, 9);
        let lu = bench(&cfg, || det_lu(a.data(), m));
        let lap = if m <= 8 {
            bench(&cfg, || det_laplace(a.data(), m)).median
        } else {
            f64::NAN
        };
        let bar = bench(&cfg, || det_bareiss(ai.data(), m).unwrap());
        t0.row(&[
            m.to_string(),
            fmt_time(lu.median),
            fmt_time(lap),
            fmt_time(bar.median),
        ]);
    }
    print!("{}", t0.render());

    println!("\n## batched engines (batch=256 lanes incl. padding)\n");
    let manifest = resolve_artifact_dir(None).map(|d| Manifest::load(&d).unwrap());
    if manifest.is_none() {
        eprintln!("(artifacts not built — xla rows skipped)");
    }
    let session = manifest.as_ref().map(|_| XlaSession::cpu().unwrap());

    let mut t1 = Table::new(&[
        "m", "engine", "batch", "per batch", "Mterms/s",
    ]);
    for m in [2usize, 4, 6, 8] {
        // A shared workload: ~full batch of gathered submatrices.
        let n = m + 8;
        let a = gen::uniform(&mut TestRng::from_seed(m as u64), m, n, -1.0, 1.0);
        let mut builder = BatchBuilder::new(m, 256);
        let mut cols: Vec<u32> = (1..=m as u32).collect();
        while !builder.is_full() {
            builder.push(&a, &cols);
            if !raddet::combin::successor(&mut cols, n as u64) {
                break;
            }
        }
        let (subs, signs, _) = builder.finalize();
        let (subs, signs) = (subs.to_vec(), signs.to_vec());

        let mut cpu = CpuEngine::new(m, 256);
        // Clone per sample: the engine consumes the batch in place (the
        // clone cost is reported separately below the table).
        let mut scratch = subs.clone();
        let s = bench(&cfg, || {
            scratch.copy_from_slice(&subs);
            cpu.run_batch(&mut scratch, &signs).unwrap()
        });
        t1.row(&[
            m.to_string(),
            "cpu-lu".into(),
            "256".into(),
            fmt_time(s.median),
            format!("{:.2}", 256.0 / s.median / 1e6),
        ]);

        if let (Some(man), Some(sess)) = (&manifest, &session) {
            let spec = man.find(m, Dtype::F64, 256).unwrap();
            let exe = sess.load(spec).unwrap();
            // exe.batch() may be 256; resize buffers if a smaller bucket
            // was chosen.
            if exe.batch() == 256 {
                let s = bench(&cfg, || exe.run(&subs, &signs).unwrap().partial);
                t1.row(&[
                    m.to_string(),
                    "xla-pjrt".into(),
                    "256".into(),
                    fmt_time(s.median),
                    format!("{:.2}", 256.0 / s.median / 1e6),
                ]);
            }
        }
    }
    print!("{}", t1.render());
    println!("\n(xla = interpret-mode Pallas via PJRT: measures dispatch overhead, not TPU perf)");
}
