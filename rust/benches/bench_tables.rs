//! Bench/regen: the paper's printed artifacts — Table 1/3 (Pascal
//! weight table), Table 2 (the 56 subsets), Example 1 — regenerated and
//! verified, with generation timing.

use raddet::bench::{bench, fmt_time, BenchConfig};
use raddet::combin::{unrank, unrank_traced, CombinationStream, PascalTable};

fn main() {
    let cfg = BenchConfig::default();

    println!("## Table 1 / Table 3 (m=5, n=8)\n");
    let t = PascalTable::new(8, 5).unwrap();
    println!("{}", t.render());
    let s = bench(&cfg, || PascalTable::new(8, 5).unwrap().at(4, 3));
    println!("generation: {}\n", fmt_time(s.median));

    println!("## Table 2 — all 56 five-member subsets of {{1..8}}\n");
    let table = PascalTable::new(8, 5).unwrap();
    let all: Vec<Vec<u32>> = CombinationStream::new(&table, 0, 56).unwrap().collect();
    for (q, c) in all.iter().enumerate() {
        print!("B{q:<2}{c:?} ");
        if q % 4 == 3 {
            println!();
        }
    }
    println!();
    // Verify against direct unranking (Theorem 2 bijectivity).
    for (q, c) in all.iter().enumerate() {
        assert_eq!(*c, unrank(8, 5, q as u128).unwrap());
    }
    let s = bench(&cfg, || {
        CombinationStream::new(&table, 0, 56).unwrap().count()
    });
    println!("\nfull Table 2 enumeration: {} ✓ verified\n", fmt_time(s.median));

    println!("## Example 1 — unrank q=49 (n=8, m=5)\n");
    let (b, stages) = unrank_traced(8, 5, 49).unwrap();
    for (i, st) in stages.iter().enumerate() {
        println!(
            "stage {}: row j={}, {} step(s), Sum={}, q {} → {}, B := {:?}",
            i + 1,
            st.row_j,
            st.steps_p,
            st.sum,
            st.q_before,
            st.q_after,
            st.b_after
        );
    }
    assert_eq!(b, vec![2, 5, 6, 7, 8]);
    println!("B49 = {b:?} ✓ (paper: [2,5,6,7,8])");
}
