//! Bench: the §6 complexity table, measured on the PRAM cost-model
//! simulator — CRCW/CREW/EREW critical-path steps across a problem
//! sweep, plus the O(n²) flatness fit.

use raddet::bench::{bench, fmt_time, BenchConfig};
use raddet::pram::{analysis, section6_table, MemPolicy, PramMachine};

fn main() {
    println!("## §6 PRAM complexity table (measured critical-path steps)\n");
    let problems = [(8u64, 5u64), (12, 6), (16, 8), (20, 10), (24, 12), (28, 14)];
    let rows = section6_table(&problems).unwrap();
    print!("{}", analysis::render(&rows));

    println!("\n## O(n²) fit (EREW, m = n/2) — time/n² must stay flat\n");
    for n in [8u64, 12, 16, 20, 24, 28, 32] {
        let r = PramMachine::new(MemPolicy::Erew).simulate(n, n / 2).unwrap();
        println!(
            "n={n:<3} C={:<14.3e} time={:<7} time/n² = {:.3}",
            r.groups as f64,
            r.time(),
            r.time() as f64 / (n * n) as f64
        );
    }

    println!("\n## simulator throughput (it measures real unrank walks)\n");
    let cfg = BenchConfig { samples: 8, ..Default::default() };
    for &(n, m) in &[(16u64, 8u64), (24, 12)] {
        let s = bench(&cfg, || {
            PramMachine::new(MemPolicy::Crew).simulate(n, m).unwrap().time()
        });
        println!("simulate({n},{m}): {}", fmt_time(s.median));
    }
}
