//! Bench: the §6/§8 `network_overhead` term — determinant latency
//! in-process vs through the TCP service (loopback), per job size.

use raddet::bench::{bench, fmt_time, BenchConfig, Table};
use raddet::combin::combination_count;
use raddet::coordinator::{Coordinator, CoordinatorConfig, EngineKind};
use raddet::matrix::gen;
use raddet::service::{Client, Server};
use raddet::testkit::TestRng;

fn main() {
    let cfg = BenchConfig { samples: 10, ..Default::default() };
    let mk = || {
        Coordinator::new(CoordinatorConfig {
            workers: 2,
            engine: EngineKind::Cpu,
            batch: 256,
            ..Default::default()
        })
        .unwrap()
    };

    let handle = Server::new(mk()).start("127.0.0.1:0").unwrap();
    let addr = handle.addr().to_string();
    let local = mk();
    let mut client = Client::connect(&addr).unwrap();
    client.ping().unwrap();

    println!("## service overhead (loopback TCP, line protocol)\n");
    let mut table = Table::new(&[
        "shape", "terms", "payload", "in-process", "via service", "overhead", "overhead/req",
    ]);
    for &(m, n) in &[(2usize, 8usize), (3, 12), (4, 16), (5, 18), (6, 20)] {
        let a = gen::uniform(&mut TestRng::from_seed((m + n) as u64), m, n, -1.0, 1.0);
        let terms = combination_count(n as u64, m as u64).unwrap();
        let payload = raddet::service::Request::Det(a.clone()).encode().len();

        let inproc = bench(&cfg, || local.radic_det(&a).unwrap().det);
        let served = bench(&cfg, || client.det(&a).unwrap().det);
        let overhead = served.median - inproc.median;

        table.row(&[
            format!("{m}×{n}"),
            terms.to_string(),
            format!("{payload} B"),
            fmt_time(inproc.median),
            fmt_time(served.median),
            fmt_time(overhead.max(0.0)),
            format!("{:.0}%", 100.0 * overhead.max(0.0) / served.median),
        ]);
    }
    print!("{}", table.render());
    println!(
        "\n(the paper's O(n² + network_overhead): overhead is flat per request —\n\
         dominated by serialization + loopback RTT, amortized as jobs grow)"
    );
    client.quit();
    handle.stop();
}
