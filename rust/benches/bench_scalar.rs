//! Bench: the scalar tower's per-term cost — f64 vs checked i128 vs
//! BigInt — across submatrix orders, plus the BigInt entry-magnitude
//! crossover (the point where i128 stops being *available* and big
//! stops being a luxury).
//!
//! Two questions, one table each:
//!
//! 1. **Per-term cost by m** (fixed small entries): what does each
//!    scalar pay per Radić term on the cpu-lu and prefix engine
//!    families? Expectation: i128 ≈ f64 within a small factor (checked
//!    ops are branch-predictable), BigInt a constant factor behind on
//!    small values (per-value allocation) that *shrinks* relatively as
//!    m grows and the O(m³) work dominates.
//! 2. **Crossover by entry magnitude** (fixed shape): sweeping entry
//!    size upward, where does checked i128 start refusing
//!    (ScalarOverflow) — i.e. from which magnitude is BigInt the only
//!    exact option? The bench prints the refusal boundary instead of
//!    pretending to time a path that errors.
//!
//! Results are recorded in EXPERIMENTS.md §Scalars. JSON rows go to
//! `RADDET_BENCH_JSON` like the other benches.

use raddet::bench::stats::{json_f64, json_object};
use raddet::bench::{bench, fmt_time, BenchConfig, Table};
use raddet::combin::{combination_count, Chunk, PascalTable};
use raddet::coordinator::{ChunkRunner, LeaseMatrix, LeasePartial};
use raddet::matrix::gen;
use raddet::scalar::ScalarKind;
use raddet::testkit::TestRng;

/// One full-space sweep through a [`ChunkRunner`] (single chunk — the
/// per-term arithmetic is what's under test, not scheduling).
fn sweep(runner: &mut ChunkRunner, a: LeaseMatrix<'_>, table: &PascalTable, total: u128) -> u64 {
    let (partial, wm) = runner
        .run_chunk(a, table, Chunk { start: 0, len: total })
        .expect("bench sweep");
    std::hint::black_box(&partial);
    wm.terms
}

fn main() {
    let cfg = BenchConfig::slow();

    println!("## per-term cost by scalar (entries in ±60, single chunk)\n");
    let mut t1 = Table::new(&[
        "m", "n", "terms", "engine", "f64", "i128", "big", "i128/f64", "big/i128",
    ]);
    let mut json_rows: Vec<String> = Vec::new();
    for (m, n) in [(3usize, 14usize), (4, 14), (5, 16), (6, 16)] {
        let total = combination_count(n as u64, m as u64).unwrap();
        let table = PascalTable::new(n as u64, m as u64).unwrap();
        let ai = gen::integer(&mut TestRng::from_seed((m * 31 + n) as u64), m, n, -60, 60);
        let af = ai.map(|x| x as f64);
        for use_prefix in [false, true] {
            let engine = if use_prefix { "prefix" } else { "cpu-lu" };
            let mut rf = ChunkRunner::new(ScalarKind::F64, use_prefix, m, 256);
            let mut ri = ChunkRunner::new(ScalarKind::I128, use_prefix, m, 256);
            let mut rb = ChunkRunner::new(ScalarKind::Big, use_prefix, m, 256);
            let s_f = bench(&cfg, || sweep(&mut rf, LeaseMatrix::F64(&af), &table, total));
            let s_i = bench(&cfg, || sweep(&mut ri, LeaseMatrix::Exact(&ai), &table, total));
            let s_b = bench(&cfg, || sweep(&mut rb, LeaseMatrix::Exact(&ai), &table, total));
            let per = |s: f64| s / total as f64;
            t1.row(&[
                m.to_string(),
                n.to_string(),
                total.to_string(),
                engine.into(),
                fmt_time(per(s_f.median)),
                fmt_time(per(s_i.median)),
                fmt_time(per(s_b.median)),
                format!("{:.2}×", s_i.median / s_f.median),
                format!("{:.2}×", s_b.median / s_i.median),
            ]);
            json_rows.push(json_object(&[
                ("bench", "\"scalar_per_term\"".into()),
                ("m", m.to_string()),
                ("n", n.to_string()),
                ("engine", format!("\"{engine}\"")),
                ("terms", total.to_string()),
                ("f64", s_f.to_json()),
                ("i128", s_i.to_json()),
                ("big", s_b.to_json()),
                ("big_over_i128", json_f64(s_b.median / s_i.median)),
            ]));
        }
    }
    print!("{}", t1.render());

    println!("\n## exact-range crossover by entry magnitude (m=5, n=12, prefix)\n");
    let (m, n) = (5usize, 12usize);
    let total = combination_count(n as u64, m as u64).unwrap();
    let table = PascalTable::new(n as u64, m as u64).unwrap();
    let mut t2 = Table::new(&["|entries| ≤", "i128", "big", "big/i128"]);
    for mag in [1_000i64, 1_000_000, 1_000_000_000, 1_000_000_000_000, i64::MAX / 4] {
        let ai = gen::integer(&mut TestRng::from_seed(mag as u64), m, n, -mag, mag);
        let mut ri = ChunkRunner::new(ScalarKind::I128, true, m, 256);
        let mut rb = ChunkRunner::new(ScalarKind::Big, true, m, 256);
        // i128 first — past its range the row records the refusal.
        let narrow = ri.run_chunk(LeaseMatrix::Exact(&ai), &table, Chunk { start: 0, len: total });
        let s_b = bench(&cfg, || sweep(&mut rb, LeaseMatrix::Exact(&ai), &table, total));
        match narrow {
            Ok((LeasePartial::Exact(_), _)) => {
                let s_i = bench(&cfg, || {
                    sweep(&mut ri, LeaseMatrix::Exact(&ai), &table, total)
                });
                t2.row(&[
                    format!("1e{}", (mag as f64).log10().round() as i64),
                    fmt_time(s_i.median),
                    fmt_time(s_b.median),
                    format!("{:.2}×", s_b.median / s_i.median),
                ]);
            }
            Ok(other) => panic!("{other:?}"),
            Err(e) => {
                t2.row(&[
                    format!("1e{}", (mag as f64).log10().round() as i64),
                    format!("refused ({e})"),
                    fmt_time(s_b.median),
                    "∞ (big only)".into(),
                ]);
            }
        }
        json_rows.push(json_object(&[
            ("bench", "\"scalar_crossover\"".into()),
            ("magnitude", mag.to_string()),
            ("terms", total.to_string()),
            ("big", s_b.to_json()),
        ]));
    }
    print!("{}", t2.render());

    // ── Scratch reuse in the exact cofactor pass ────────────────────
    // The exact engines' per-block hot path is cofactors: m Bareiss
    // minors per sibling block. The allocating form builds a fresh
    // scalar working copy per minor (for BigInt, a limb vector per
    // element); the scratch form recycles one CofactorScratch across
    // blocks via Scalar::assign_elem. This is the win the engines now
    // take by default (EXPERIMENTS.md §Scalars).
    println!("\n## exact scratch reuse (one cofactor pass per iteration)\n");
    use raddet::linalg::{cofactors_generic, cofactors_into, CofactorScratch};
    use raddet::scalar::BigInt;
    let mut t3 = Table::new(&["m", "scalar", "alloc", "scratch", "speedup"]);
    for m in [4usize, 5, 6] {
        let prefix = gen::integer(&mut TestRng::from_seed(m as u64 * 7 + 1), m, m - 1, -60, 60);
        // BigInt: the scalar the hoist exists for.
        let mut out_b = vec![BigInt::default(); m];
        let mut minor_buf = Vec::new();
        let s_alloc_b = bench(&cfg, || {
            cofactors_generic::<BigInt>(prefix.data(), m, &mut minor_buf, &mut out_b).unwrap();
            std::hint::black_box(&out_b);
        });
        let mut scratch_b: CofactorScratch<BigInt> = CofactorScratch::new();
        let s_scr_b = bench(&cfg, || {
            cofactors_into(prefix.data(), m, &mut scratch_b, &mut out_b).unwrap();
            std::hint::black_box(&out_b);
        });
        // i128: Copy scalar — measures pure buffer-reuse overhead.
        let mut out_i = vec![0i128; m];
        let s_alloc_i = bench(&cfg, || {
            cofactors_generic::<i128>(prefix.data(), m, &mut minor_buf, &mut out_i).unwrap();
            std::hint::black_box(&out_i);
        });
        let mut scratch_i: CofactorScratch<i128> = CofactorScratch::new();
        let s_scr_i = bench(&cfg, || {
            cofactors_into(prefix.data(), m, &mut scratch_i, &mut out_i).unwrap();
            std::hint::black_box(&out_i);
        });
        for (kind, s_alloc, s_scr) in
            [("big", &s_alloc_b, &s_scr_b), ("i128", &s_alloc_i, &s_scr_i)]
        {
            t3.row(&[
                m.to_string(),
                kind.to_string(),
                fmt_time(s_alloc.median),
                fmt_time(s_scr.median),
                format!("{:.2}×", s_alloc.median / s_scr.median),
            ]);
            json_rows.push(json_object(&[
                ("bench", "\"scalar_scratch\"".into()),
                ("m", m.to_string()),
                ("scalar", format!("\"{kind}\"")),
                ("alloc", s_alloc.to_json()),
                ("scratch", s_scr.to_json()),
                ("speedup", json_f64(s_alloc.median / s_scr.median)),
            ]));
        }
    }
    print!("{}", t3.render());

    let json = format!("[\n  {}\n]\n", json_rows.join(",\n  "));
    match std::env::var("RADDET_BENCH_JSON") {
        Ok(path) if !path.is_empty() => {
            std::fs::write(&path, &json).expect("write bench json");
            println!("\n(JSON written to {path})");
        }
        _ => {
            println!("\n## JSON (set RADDET_BENCH_JSON=path to write a file)\n");
            print!("{json}");
        }
    }
}
