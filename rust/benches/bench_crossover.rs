//! Bench: sequential vs coordinator crossover — at what job size does
//! the parallel machinery (threads, batching, chunk setup) amortize?
//!
//! The paper's §7 concedes that parallelism has overhead (“in most
//! cases, increasing the number of processors does not increase
//! productivity”); this bench locates that boundary on this testbed.

use raddet::bench::{bench, fmt_time, BenchConfig, Table};
use raddet::combin::combination_count;
use raddet::coordinator::{Coordinator, CoordinatorConfig, EngineKind, Schedule};
use raddet::linalg::radic_det_seq;
use raddet::matrix::gen;
use raddet::testkit::TestRng;

fn main() {
    let cfg = BenchConfig { samples: 8, ..Default::default() };
    println!("## sequential vs coordinator crossover (cpu-lu)\n");

    let workers = std::thread::available_parallelism().map_or(2, |p| p.get()).max(2);
    let coord = Coordinator::new(CoordinatorConfig {
        workers,
        engine: EngineKind::Cpu,
        schedule: Schedule::Static,
        batch: 256,
        ..Default::default()
    })
    .unwrap();

    let mut table = Table::new(&[
        "m", "n", "terms", "sequential", "coordinator", "ratio",
    ]);
    // Sweep job sizes from trivial to ~1M terms.
    for &(m, n) in &[
        (3usize, 8usize), // 56
        (3, 12),          // 220
        (4, 14),          // 1001
        (4, 18),          // 3060
        (5, 20),          // 15504
        (5, 24),          // 42504
        (6, 24),          // 134596
        (6, 28),          // 376740
        (7, 28),          // 1184040
    ] {
        let a = gen::uniform(&mut TestRng::from_seed((m * 100 + n) as u64), m, n, -1.0, 1.0);
        let terms = combination_count(n as u64, m as u64).unwrap();

        let seq = bench(&cfg, || radic_det_seq(&a).unwrap());
        let par = bench(&cfg, || coord.radic_det(&a).unwrap().det);

        table.row(&[
            m.to_string(),
            n.to_string(),
            terms.to_string(),
            fmt_time(seq.median),
            fmt_time(par.median),
            format!("{:.2}×", seq.median / par.median),
        ]);
    }
    print!("{}", table.render());
    println!(
        "\n(ratio > 1 ⇒ coordinator wins; with {workers} workers on this testbed the\n\
         crossover marks where thread+batch setup amortizes)"
    );
}
