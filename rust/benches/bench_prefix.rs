//! Bench: prefix-factored engine vs cpu-lu across the (m, n) plane —
//! the amortization claim in numbers (terms/sec, same worker count).
//!
//! Emits both the usual markdown table and machine-readable JSON
//! records (via `bench::stats`) to seed the `BENCH_prefix.json` perf
//! trajectory: set `RADDET_BENCH_JSON=path` to write the file, else
//! the JSON lines print to stdout after the table.
//!
//! Expectation (EXPERIMENTS.md §Perf iteration 6): speedup grows with
//! m (the LU being amortized is O(m³)) and with n (wider sibling
//! blocks); ≥ 5× for m ≥ 5, n ≥ 20 on a fixed worker count.

use raddet::bench::stats::{json_f64, json_object};
use raddet::bench::{bench, fmt_time, BenchConfig, Table};
use raddet::combin::{combination_count, Chunk, PascalTable};
use raddet::coordinator::{Coordinator, CoordinatorConfig, EngineKind, LeaseRunner, Schedule};
use raddet::linalg::KernelKind;
use raddet::matrix::gen;
use raddet::testkit::TestRng;

const WORKERS: usize = 4;
/// Keep the sweep under ~a minute: skip shapes beyond this many terms.
const TERM_BUDGET: u128 = 4_000_000;

fn coord(engine: EngineKind) -> Coordinator {
    Coordinator::new(CoordinatorConfig {
        workers: WORKERS,
        engine,
        schedule: Schedule::Static,
        ..Default::default()
    })
    .unwrap()
}

fn main() {
    let cfg = BenchConfig::slow();
    let cpu = coord(EngineKind::Cpu);
    let prefix = coord(EngineKind::Prefix);

    println!("## prefix engine vs cpu-lu ({WORKERS} workers, static schedule)\n");
    let mut table = Table::new(&[
        "m", "n", "terms", "cpu-lu", "prefix", "cpu Mterms/s", "prefix Mterms/s", "speedup",
    ]);
    let mut json_rows: Vec<String> = Vec::new();

    for m in 3usize..=8 {
        for n in [12usize, 16, 20, 24, 28] {
            if n < m {
                continue;
            }
            let terms = combination_count(n as u64, m as u64).unwrap();
            if terms > TERM_BUDGET {
                eprintln!("(skip m={m} n={n}: {terms} terms over budget)");
                continue;
            }
            let a = gen::uniform(&mut TestRng::from_seed((m * 100 + n) as u64), m, n, -1.0, 1.0);

            // Sanity first: both engines must agree before we time them.
            let d_cpu = cpu.radic_det(&a).unwrap().det;
            let d_pre = prefix.radic_det(&a).unwrap().det;
            assert!(
                (d_cpu - d_pre).abs() < 1e-9 * d_cpu.abs().max(1.0),
                "m={m} n={n}: engines disagree ({d_cpu} vs {d_pre})"
            );

            let s_cpu = bench(&cfg, || cpu.radic_det(&a).unwrap().det);
            let s_pre = bench(&cfg, || prefix.radic_det(&a).unwrap().det);
            let tput_cpu = terms as f64 / s_cpu.median;
            let tput_pre = terms as f64 / s_pre.median;
            let speedup = s_cpu.median / s_pre.median;
            table.row(&[
                m.to_string(),
                n.to_string(),
                terms.to_string(),
                fmt_time(s_cpu.median),
                fmt_time(s_pre.median),
                format!("{:.2}", tput_cpu / 1e6),
                format!("{:.2}", tput_pre / 1e6),
                format!("{speedup:.2}×"),
            ]);
            json_rows.push(json_object(&[
                ("bench", "\"prefix_vs_cpu\"".into()),
                ("m", m.to_string()),
                ("n", n.to_string()),
                ("workers", WORKERS.to_string()),
                ("terms", terms.to_string()),
                ("cpu", s_cpu.to_json()),
                ("prefix", s_pre.to_json()),
                ("speedup", json_f64(speedup)),
            ]));
        }
    }
    print!("{}", table.render());

    // ── Dot kernel in isolation ─────────────────────────────────────
    // The dispatched dot on the widest sibling block of each shape
    // (width = n−m+1, i.e. prefix = columns 1…m−1): this is the unit
    // the SIMD layer vectorizes, and where the ≥ 1.5× acceptance gate
    // of EXPERIMENTS.md §Perf iteration 7 is measured. Per-lane det
    // bits must agree across kernels before any timing counts.
    //
    // The end-to-end sweep below is necessarily flatter: a full (m,n)
    // sweep averages block width n/m, so the O(m³) cofactor
    // factorization — identical across kernels — takes a growing share
    // of per-block time as m rises.
    let kernels = KernelKind::available_kernels();
    let names: Vec<&str> = kernels.iter().map(|k| k.as_str()).collect();
    const DOT_REPS: usize = 4096;
    println!(
        "\n## dot kernel in isolation ({DOT_REPS}× widest block per sample, kernels: {})\n",
        names.join("/")
    );
    let mut dt = Table::new(&["m", "n", "width", "kernel", "per block", "Mterms/s", "vs scalar"]);
    for m in [4usize, 6, 8, 10] {
        for n in [m + 12, m + 20] {
            let w = n - m + 1;
            let a = gen::uniform(&mut TestRng::from_seed((m * 37 + n) as u64), m, n, -1.0, 1.0);
            let cof: Vec<f64> = (0..m).map(|i| (0.3 + 0.37 * i as f64).sin()).collect();
            let c0 = m - 1; // widest block's first sibling column (0-based)
            let mut dets = vec![0.0; w];
            let mut scalar_median = None;
            let mut want_bits: Option<Vec<u64>> = None;
            for &k in &kernels {
                k.dot_block(a.data(), n, c0, &cof, &mut dets);
                let bits: Vec<u64> = dets.iter().map(|d| d.to_bits()).collect();
                match &want_bits {
                    None => want_bits = Some(bits),
                    Some(wb) => assert_eq!(&bits, wb, "kernel {k} lane bits (m={m} n={n})"),
                }
                let s = bench(&cfg, || {
                    for _ in 0..DOT_REPS {
                        k.dot_block(a.data(), n, c0, &cof, &mut dets);
                    }
                    std::hint::black_box(&dets);
                });
                let per_block = s.median / DOT_REPS as f64;
                if k == KernelKind::Scalar {
                    scalar_median = Some(s.median);
                }
                let speedup = scalar_median.expect("scalar runs first") / s.median;
                dt.row(&[
                    m.to_string(),
                    n.to_string(),
                    w.to_string(),
                    k.as_str().to_string(),
                    fmt_time(per_block),
                    format!("{:.1}", w as f64 / per_block / 1e6),
                    format!("{speedup:.2}×"),
                ]);
                json_rows.push(json_object(&[
                    ("bench", "\"prefix_kernels\"".into()),
                    ("m", m.to_string()),
                    ("n", n.to_string()),
                    ("width", w.to_string()),
                    ("kernel", format!("\"{k}\"")),
                    ("stats", s.to_json()),
                    ("reps", DOT_REPS.to_string()),
                    ("speedup_vs_scalar", json_f64(speedup)),
                ]));
            }
        }
    }
    print!("{}", dt.render());

    // ── Per-kernel end-to-end sweep ─────────────────────────────────
    // One single-chunk LeaseRunner per kernel (scheduling out of the
    // picture): the whole prefix engine — block enumeration, cofactor
    // LU, dispatched dots, Neumaier — under each kernel. Partials must
    // be bit-identical across kernels before any timing counts.
    println!("\n## prefix engine per kernel (single chunk, end to end)\n");
    let mut kt = Table::new(&["m", "n", "terms", "kernel", "median", "Mterms/s", "vs scalar"]);
    for m in [4usize, 6, 8, 10] {
        for n in [m + 12, m + 20] {
            let terms = combination_count(n as u64, m as u64).unwrap();
            if terms > TERM_BUDGET {
                eprintln!("(skip kernels m={m} n={n}: {terms} terms over budget)");
                continue;
            }
            let a = gen::uniform(&mut TestRng::from_seed((m * 1000 + n) as u64), m, n, -1.0, 1.0);
            let ptable = PascalTable::new(n as u64, m as u64).unwrap();
            let chunk = Chunk { start: 0, len: terms };
            let mut scalar_median = None;
            let mut want_bits = None;
            for &k in &kernels {
                let mut runner = LeaseRunner::<f64>::prefix_with_kernel(m, k);
                let (v, _) = runner.run_chunk(&a, &ptable, chunk).unwrap();
                match want_bits {
                    None => want_bits = Some(v.to_bits()),
                    Some(w) => assert_eq!(
                        v.to_bits(),
                        w,
                        "kernel {k} diverged from scalar bits (m={m} n={n})"
                    ),
                }
                let s = bench(&cfg, || {
                    let (v, _) = runner.run_chunk(&a, &ptable, chunk).unwrap();
                    v
                });
                if k == KernelKind::Scalar {
                    scalar_median = Some(s.median);
                }
                let speedup = scalar_median.expect("scalar runs first") / s.median;
                kt.row(&[
                    m.to_string(),
                    n.to_string(),
                    terms.to_string(),
                    k.as_str().to_string(),
                    fmt_time(s.median),
                    format!("{:.2}", terms as f64 / s.median / 1e6),
                    format!("{speedup:.2}×"),
                ]);
                json_rows.push(json_object(&[
                    ("bench", "\"prefix_kernels_e2e\"".into()),
                    ("m", m.to_string()),
                    ("n", n.to_string()),
                    ("terms", terms.to_string()),
                    ("kernel", format!("\"{k}\"")),
                    ("stats", s.to_json()),
                    ("speedup_vs_scalar", json_f64(speedup)),
                ]));
            }
        }
    }
    print!("{}", kt.render());

    let json = format!("[\n  {}\n]\n", json_rows.join(",\n  "));
    match std::env::var("RADDET_BENCH_JSON") {
        Ok(path) if !path.is_empty() => {
            std::fs::write(&path, &json).expect("write bench json");
            println!("\n(JSON written to {path})");
        }
        _ => {
            println!("\n## JSON (set RADDET_BENCH_JSON=path to write a file)\n");
            print!("{json}");
        }
    }
}
