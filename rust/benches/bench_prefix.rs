//! Bench: prefix-factored engine vs cpu-lu across the (m, n) plane —
//! the amortization claim in numbers (terms/sec, same worker count).
//!
//! Emits both the usual markdown table and machine-readable JSON
//! records (via `bench::stats`) to seed the `BENCH_prefix.json` perf
//! trajectory: set `RADDET_BENCH_JSON=path` to write the file, else
//! the JSON lines print to stdout after the table.
//!
//! Expectation (EXPERIMENTS.md §Perf iteration 6): speedup grows with
//! m (the LU being amortized is O(m³)) and with n (wider sibling
//! blocks); ≥ 5× for m ≥ 5, n ≥ 20 on a fixed worker count.

use raddet::bench::stats::{json_f64, json_object};
use raddet::bench::{bench, fmt_time, BenchConfig, Table};
use raddet::combin::combination_count;
use raddet::coordinator::{Coordinator, CoordinatorConfig, EngineKind, Schedule};
use raddet::matrix::gen;
use raddet::testkit::TestRng;

const WORKERS: usize = 4;
/// Keep the sweep under ~a minute: skip shapes beyond this many terms.
const TERM_BUDGET: u128 = 4_000_000;

fn coord(engine: EngineKind) -> Coordinator {
    Coordinator::new(CoordinatorConfig {
        workers: WORKERS,
        engine,
        schedule: Schedule::Static,
        ..Default::default()
    })
    .unwrap()
}

fn main() {
    let cfg = BenchConfig::slow();
    let cpu = coord(EngineKind::Cpu);
    let prefix = coord(EngineKind::Prefix);

    println!("## prefix engine vs cpu-lu ({WORKERS} workers, static schedule)\n");
    let mut table = Table::new(&[
        "m", "n", "terms", "cpu-lu", "prefix", "cpu Mterms/s", "prefix Mterms/s", "speedup",
    ]);
    let mut json_rows: Vec<String> = Vec::new();

    for m in 3usize..=8 {
        for n in [12usize, 16, 20, 24, 28] {
            if n < m {
                continue;
            }
            let terms = combination_count(n as u64, m as u64).unwrap();
            if terms > TERM_BUDGET {
                eprintln!("(skip m={m} n={n}: {terms} terms over budget)");
                continue;
            }
            let a = gen::uniform(&mut TestRng::from_seed((m * 100 + n) as u64), m, n, -1.0, 1.0);

            // Sanity first: both engines must agree before we time them.
            let d_cpu = cpu.radic_det(&a).unwrap().det;
            let d_pre = prefix.radic_det(&a).unwrap().det;
            assert!(
                (d_cpu - d_pre).abs() < 1e-9 * d_cpu.abs().max(1.0),
                "m={m} n={n}: engines disagree ({d_cpu} vs {d_pre})"
            );

            let s_cpu = bench(&cfg, || cpu.radic_det(&a).unwrap().det);
            let s_pre = bench(&cfg, || prefix.radic_det(&a).unwrap().det);
            let tput_cpu = terms as f64 / s_cpu.median;
            let tput_pre = terms as f64 / s_pre.median;
            let speedup = s_cpu.median / s_pre.median;
            table.row(&[
                m.to_string(),
                n.to_string(),
                terms.to_string(),
                fmt_time(s_cpu.median),
                fmt_time(s_pre.median),
                format!("{:.2}", tput_cpu / 1e6),
                format!("{:.2}", tput_pre / 1e6),
                format!("{speedup:.2}×"),
            ]);
            json_rows.push(json_object(&[
                ("bench", "\"prefix_vs_cpu\"".into()),
                ("m", m.to_string()),
                ("n", n.to_string()),
                ("workers", WORKERS.to_string()),
                ("terms", terms.to_string()),
                ("cpu", s_cpu.to_json()),
                ("prefix", s_pre.to_json()),
                ("speedup", json_f64(speedup)),
            ]));
        }
    }
    print!("{}", table.render());

    let json = format!("[\n  {}\n]\n", json_rows.join(",\n  "));
    match std::env::var("RADDET_BENCH_JSON") {
        Ok(path) if !path.is_empty() => {
            std::fs::write(&path, &json).expect("write bench json");
            println!("\n(JSON written to {path})");
        }
        _ => {
            println!("\n## JSON (set RADDET_BENCH_JSON=path to write a file)\n");
            print!("{json}");
        }
    }
}
