/* kernel_bench — C transliteration of the raddet prefix-dot SIMD
 * kernels and their bench harness, for machines with a C compiler but
 * no Rust toolchain (the authoring container). It exists to produce
 * *measured* numbers for the perf trajectory when `cargo bench` cannot
 * run locally; CI's `Perf benches` step regenerates the native numbers
 * and uploads them as the BENCH_PR10 artifact (the ground truth).
 *
 * What is transliterated (kept line-for-line close to
 * rust/src/linalg/simd.rs and rust/src/coordinator/engine.rs — if you
 * change a kernel there, change it here):
 *
 *   dot_scalar / dot_unrolled / dot_avx2   the three x86 dot kernels,
 *       including the determinism rule: identical per-lane sequential
 *       fold, mul then add, never fmadd (compiled with -ffp-contract=off
 *       so the C compiler cannot fuse behind our back);
 *   cofactors()                            MinorsWorkspace's packed-LU
 *       Laplace cofactors;
 *   full-sweep "engine" loop               prefix enumeration + gather
 *       + cofactors + dispatched dot + alternating sign + Neumaier;
 *   det_bareiss_i128                       the exact path's fraction-
 *       free elimination, timed alloc-per-call vs reused scratch.
 *
 * Three measurements, mirroring rust/benches/bench_prefix.rs and
 * bench_scalar.rs:
 *   1. dot kernel in isolation (widest block of each (m,n)) — the
 *      vectorization gate;
 *   2. full-sweep per kernel — end-to-end speedup with the cofactor
 *      LU (kernel-independent) included;
 *   3. i128 Bareiss cofactor pass, alloc vs scratch.
 *
 * Bit-identity across kernels is asserted before any timing counts,
 * both on random geometries and on every full sweep.
 *
 * Build & run:   ./run.sh     (gcc -O3 -mavx2 -ffp-contract=off …)
 */

#include <math.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

#ifdef __AVX2__
#include <immintrin.h>
#endif

/* ── deterministic fill (splitmix64) ─────────────────────────────── */

static uint64_t rng_state;

static uint64_t rng_next(void) {
    uint64_t z = (rng_state += 0x9e3779b97f4a7c15ULL);
    z = (z ^ (z >> 30)) * 0xbf58476d1ce4e5b9ULL;
    z = (z ^ (z >> 27)) * 0x94d049bb133111ebULL;
    return z ^ (z >> 31);
}

static double rng_uniform(double lo, double hi) {
    double u = (double)(rng_next() >> 11) / 9007199254740992.0; /* [0,1) */
    return lo + u * (hi - lo);
}

static int64_t rng_int(int64_t lo, int64_t hi) {
    return lo + (int64_t)(rng_next() % (uint64_t)(hi - lo + 1));
}

/* ── the dot kernels (transliterated from linalg/simd.rs) ────────── */

static void dot_scalar(const double *data, size_t n, size_t c0,
                       const double *cof, size_t m, double *out, size_t w) {
    for (size_t t = 0; t < w; t++) {
        size_t col = c0 + t;
        double det = 0.0;
        for (size_t i = 0; i < m; i++)
            det += cof[i] * data[i * n + col];
        out[t] = det;
    }
}

static void dot_tail(const double *data, size_t n, size_t c0,
                     const double *cof, size_t m, double *out, size_t w,
                     size_t t0) {
    if (t0 < w)
        dot_scalar(data, n, c0 + t0, cof, m, out + t0, w - t0);
}

static void dot_unrolled(const double *data, size_t n, size_t c0,
                         const double *cof, size_t m, double *out, size_t w) {
    size_t t = 0;
    while (t + 4 <= w) {
        double a0 = 0.0, a1 = 0.0, a2 = 0.0, a3 = 0.0;
        for (size_t i = 0; i < m; i++) {
            const double *row = data + i * n + c0 + t;
            double c = cof[i];
            a0 += c * row[0];
            a1 += c * row[1];
            a2 += c * row[2];
            a3 += c * row[3];
        }
        out[t] = a0;
        out[t + 1] = a1;
        out[t + 2] = a2;
        out[t + 3] = a3;
        t += 4;
    }
    dot_tail(data, n, c0, cof, m, out, w, t);
}

#ifdef __AVX2__
static void dot_avx2(const double *data, size_t n, size_t c0,
                     const double *cof, size_t m, double *out, size_t w) {
    const double *base = data + c0;
    size_t t = 0;
    while (t + 8 <= w) {
        __m256d acc0 = _mm256_setzero_pd();
        __m256d acc1 = _mm256_setzero_pd();
        for (size_t i = 0; i < m; i++) {
            __m256d cv = _mm256_set1_pd(cof[i]);
            const double *p = base + i * n + t;
            /* mul then add, never fmadd — the determinism rule. */
            acc0 = _mm256_add_pd(acc0, _mm256_mul_pd(cv, _mm256_loadu_pd(p)));
            acc1 = _mm256_add_pd(acc1, _mm256_mul_pd(cv, _mm256_loadu_pd(p + 4)));
        }
        _mm256_storeu_pd(out + t, acc0);
        _mm256_storeu_pd(out + t + 4, acc1);
        t += 8;
    }
    if (t + 4 <= w) {
        __m256d acc = _mm256_setzero_pd();
        for (size_t i = 0; i < m; i++) {
            __m256d cv = _mm256_set1_pd(cof[i]);
            acc = _mm256_add_pd(acc,
                                _mm256_mul_pd(cv, _mm256_loadu_pd(base + i * n + t)));
        }
        _mm256_storeu_pd(out + t, acc);
        t += 4;
    }
    dot_tail(data, n, c0, cof, m, out, w, t);
}
#endif

typedef void (*dot_fn)(const double *, size_t, size_t, const double *, size_t,
                       double *, size_t);

static const char *KERNEL_NAMES[] = {"scalar", "unrolled",
#ifdef __AVX2__
                                     "avx2"
#endif
};
static const dot_fn KERNELS[] = {dot_scalar, dot_unrolled,
#ifdef __AVX2__
                                 dot_avx2
#endif
};
static const size_t NKERNELS = sizeof(KERNELS) / sizeof(KERNELS[0]);

/* ── Neumaier sum (linalg/accum.rs) ──────────────────────────────── */

typedef struct {
    double sum, comp;
} neumaier;

static void neu_add(neumaier *s, double x) {
    double t = s->sum + x;
    if (fabs(s->sum) >= fabs(x))
        s->comp += (s->sum - t) + x;
    else
        s->comp += (x - t) + s->sum;
    s->sum = t;
}

static double neu_value(const neumaier *s) { return s->sum + s->comp; }

/* ── MinorsWorkspace::cofactors (linalg/minors.rs) ───────────────── */

/* Laplace cofactors of the row-major m×(m−1) prefix; returns 0 on a
 * rank-deficient prefix (caller would fall back — the random data here
 * never triggers it, and the harness asserts so). */
static int cofactors(const double *prefix, size_t m, double *lu, double *y,
                     size_t *perm, double *out) {
    if (m == 1) {
        out[0] = 1.0;
        return 1;
    }
    size_t w = m - 1;
    memcpy(lu, prefix, m * w * sizeof(double));
    for (size_t j = 0; j < m; j++)
        perm[j] = j;
    double maxabs = 0.0;
    for (size_t i = 0; i < m * w; i++) {
        double a = fabs(prefix[i]);
        if (a > maxabs) maxabs = a;
    }
    double tiny = maxabs * (double)m * 2.220446049250313e-16 * 16.0;

    double sign = 1.0, prod = 1.0;
    for (size_t k = 0; k < w; k++) {
        size_t p = k;
        double best = fabs(lu[k * w + k]);
        for (size_t r = k + 1; r < m; r++) {
            double mag = fabs(lu[r * w + k]);
            if (mag > best) { best = mag; p = r; }
        }
        if (best <= tiny) return 0;
        if (p != k) {
            for (size_t c = 0; c < w; c++) {
                double tmp = lu[k * w + c];
                lu[k * w + c] = lu[p * w + c];
                lu[p * w + c] = tmp;
            }
            size_t tp = perm[k];
            perm[k] = perm[p];
            perm[p] = tp;
            sign = -sign;
        }
        double pivot = lu[k * w + k];
        prod *= pivot;
        double inv = 1.0 / pivot;
        for (size_t r = k + 1; r < m; r++) {
            double f = lu[r * w + k] * inv;
            lu[r * w + k] = f;
            if (f != 0.0)
                for (size_t c = k + 1; c < w; c++)
                    lu[r * w + c] -= f * lu[k * w + c];
        }
    }
    y[m - 1] = 1.0;
    for (size_t r = m - 1; r-- > 0;) {
        double s = 0.0;
        for (size_t q = r + 1; q < m; q++)
            s += y[q] * lu[q * w + r];
        y[r] = -s;
    }
    double scale = sign * prod;
    for (size_t j = 0; j < m; j++)
        out[perm[j]] = scale * y[j];
    return 1;
}

/* ── timing ──────────────────────────────────────────────────────── */

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return (double)ts.tv_sec + (double)ts.tv_nsec * 1e-9;
}

static int cmp_double(const void *a, const void *b) {
    double x = *(const double *)a, y = *(const double *)b;
    return (x > y) - (x < y);
}

#define SAMPLES 31

/* median wall time of fn() over SAMPLES runs (3 warmups) */
#define MEDIAN_OF(out_med, body)                                              \
    do {                                                                      \
        double samples_[SAMPLES];                                             \
        for (int s_ = -3; s_ < SAMPLES; s_++) {                               \
            double t0_ = now_s();                                             \
            body;                                                             \
            double dt_ = now_s() - t0_;                                       \
            if (s_ >= 0) samples_[s_] = dt_;                                  \
        }                                                                     \
        qsort(samples_, SAMPLES, sizeof(double), cmp_double);                 \
        (out_med) = samples_[SAMPLES / 2];                                    \
    } while (0)

static volatile double sink; /* defeats dead-code elimination */

/* ── 1. bit-identity sweep ───────────────────────────────────────── */

static void check_bit_identity(void) {
    rng_state = 0x5EED;
    double data[12 * 64], cof[12], ref[40], got[40];
    for (int trial = 0; trial < 5000; trial++) {
        size_t m = 1 + rng_next() % 10;
        size_t w = 1 + rng_next() % 24;
        size_t n = w + rng_next() % 24;
        size_t c0 = rng_next() % (n - w + 1);
        for (size_t i = 0; i < m * n; i++) data[i] = rng_uniform(-2.0, 2.0);
        for (size_t i = 0; i < m; i++) cof[i] = rng_uniform(-2.0, 2.0);
        dot_scalar(data, n, c0, cof, m, ref, w);
        for (size_t k = 1; k < NKERNELS; k++) {
            KERNELS[k](data, n, c0, cof, m, got, w);
            if (memcmp(ref, got, w * sizeof(double)) != 0) {
                fprintf(stderr, "BIT MISMATCH kernel=%s m=%zu w=%zu n=%zu c0=%zu\n",
                        KERNEL_NAMES[k], m, w, n, c0);
                exit(1);
            }
        }
    }
    fprintf(stderr, "bit-identity: 5000 random geometries OK (%zu kernels)\n",
            NKERNELS);
}

/* ── 2. dot kernel in isolation ──────────────────────────────────── */

#define DOT_REPS 4096

static void bench_dot_isolated(void) {
    printf("## dot kernel in isolation (%d x widest block per sample)\n", DOT_REPS);
    printf("%-3s %-3s %-6s %-9s %12s %10s %10s\n", "m", "n", "width", "kernel",
           "per block", "Mterms/s", "vs scalar");
    static const size_t MS[] = {4, 6, 8, 10};
    for (size_t mi = 0; mi < 4; mi++) {
        size_t m = MS[mi];
        size_t ns[2] = {m + 12, m + 20};
        for (int nj = 0; nj < 2; nj++) {
            size_t n = ns[nj], w = n - m + 1, c0 = m - 1;
            double *data = malloc(m * n * sizeof(double));
            double cof[16], dets[40];
            rng_state = m * 37 + n;
            for (size_t i = 0; i < m * n; i++) data[i] = rng_uniform(-1.0, 1.0);
            for (size_t i = 0; i < m; i++) cof[i] = sin(0.3 + 0.37 * (double)i);
            double scalar_med = 0.0;
            for (size_t k = 0; k < NKERNELS; k++) {
                double med;
                MEDIAN_OF(med, {
                    for (int r = 0; r < DOT_REPS; r++)
                        KERNELS[k](data, n, c0, cof, m, dets, w);
                    sink = dets[0];
                });
                if (k == 0) scalar_med = med;
                double per_block = med / DOT_REPS;
                printf("%-3zu %-3zu %-6zu %-9s %10.1f ns %10.1f %9.2fx\n", m, n, w,
                       KERNEL_NAMES[k], per_block * 1e9,
                       (double)w / per_block / 1e6, scalar_med / med);
                printf("JSON {\"bench\":\"prefix_kernels\",\"m\":%zu,\"n\":%zu,"
                       "\"width\":%zu,\"kernel\":\"%s\",\"per_block_ns\":%.1f,"
                       "\"mterms_per_s\":%.1f,\"speedup_vs_scalar\":%.2f}\n",
                       m, n, w, KERNEL_NAMES[k], per_block * 1e9,
                       (double)w / per_block / 1e6, scalar_med / med);
            }
            free(data);
        }
    }
}

/* ── 3. full-sweep "engine" per kernel ───────────────────────────── */

/* One full C(n,m) sweep with the prefix engine's structure: enumerate
 * (m−1)-column prefixes (1-based, strictly increasing), per block
 * gather the prefix, LU its cofactors, dot-dispatch the sibling lanes,
 * alternate the Radić sign, Neumaier-accumulate. Returns the det. */
static double full_sweep(const double *a, size_t m, size_t n, dot_fn kernel,
                         uint64_t *terms_out) {
    double prefix_buf[16 * 15], lu[16 * 15], yv[16], cof[16], dets[64];
    size_t perm[16];
    uint32_t c[16]; /* 1-based prefix columns */
    neumaier acc = {0.0, 0.0};
    uint64_t terms = 0;
    uint64_t r = m * (m + 1) / 2;

    if (m == 1) {
        cof[0] = 1.0;
        kernel(a, n, 0, cof, 1, dets, n);
        double sign = (r + 1) % 2 == 0 ? 1.0 : -1.0;
        for (size_t t = 0; t < n; t++, sign = -sign)
            neu_add(&acc, sign * dets[t]);
        *terms_out = n;
        return neu_value(&acc);
    }

    for (size_t i = 0; i < m - 1; i++) c[i] = (uint32_t)(i + 1);
    for (;;) {
        uint32_t last_lo = c[m - 2] + 1;
        if (last_lo <= n) {
            size_t w = n - last_lo + 1;
            /* gather m×(m−1) prefix */
            for (size_t i = 0; i < m; i++)
                for (size_t j = 0; j < m - 1; j++)
                    prefix_buf[i * (m - 1) + j] = a[i * n + (c[j] - 1)];
            if (!cofactors(prefix_buf, m, lu, yv, perm, cof)) {
                fprintf(stderr, "unexpected rank-deficient prefix\n");
                exit(1);
            }
            kernel(a, n, last_lo - 1, cof, m, dets, w);
            uint64_t s = last_lo;
            for (size_t i = 0; i < m - 1; i++) s += c[i];
            double sign = (r + s) % 2 == 0 ? 1.0 : -1.0;
            for (size_t t = 0; t < w; t++, sign = -sign)
                neu_add(&acc, sign * dets[t]);
            terms += w;
        }
        /* next (m−1)-combination of {1..n−1} */
        size_t i = m - 1;
        while (i-- > 0) {
            if (c[i] < n - 1 - (m - 2 - i)) {
                c[i]++;
                for (size_t j = i + 1; j < m - 1; j++) c[j] = c[j - 1] + 1;
                break;
            }
            if (i == 0) goto done;
        }
    }
done:
    *terms_out = terms;
    return neu_value(&acc);
}

static void bench_full_sweep(void) {
    printf("\n## full prefix sweep per kernel (engine structure end to end)\n");
    printf("%-3s %-3s %9s %-9s %12s %10s %10s\n", "m", "n", "terms", "kernel",
           "median", "Mterms/s", "vs scalar");
    static const size_t MS[] = {4, 6, 8, 10};
    for (size_t mi = 0; mi < 4; mi++) {
        size_t m = MS[mi];
        size_t ns[2] = {m + 12, m + 20};
        for (int nj = 0; nj < 2; nj++) {
            size_t n = ns[nj];
            /* term budget: skip > 4M (mirrors bench_prefix) */
            double lt = lgamma((double)n + 1) - lgamma((double)m + 1) -
                        lgamma((double)(n - m) + 1);
            if (lt > log(4e6)) {
                fprintf(stderr, "(skip m=%zu n=%zu: over term budget)\n", m, n);
                continue;
            }
            double *a = malloc(m * n * sizeof(double));
            rng_state = m * 1000 + n;
            for (size_t i = 0; i < m * n; i++) a[i] = rng_uniform(-1.0, 1.0);
            uint64_t terms = 0, ref_bits = 0;
            double scalar_med = 0.0;
            for (size_t k = 0; k < NKERNELS; k++) {
                double det = full_sweep(a, m, n, KERNELS[k], &terms);
                uint64_t bits;
                memcpy(&bits, &det, 8);
                if (k == 0)
                    ref_bits = bits;
                else if (bits != ref_bits) {
                    fprintf(stderr, "FULL-SWEEP BIT MISMATCH kernel=%s m=%zu n=%zu\n",
                            KERNEL_NAMES[k], m, n);
                    exit(1);
                }
                double med;
                MEDIAN_OF(med, {
                    uint64_t t_;
                    sink = full_sweep(a, m, n, KERNELS[k], &t_);
                });
                if (k == 0) scalar_med = med;
                printf("%-3zu %-3zu %9llu %-9s %10.2f ms %10.2f %9.2fx\n", m, n,
                       (unsigned long long)terms, KERNEL_NAMES[k], med * 1e3,
                       (double)terms / med / 1e6, scalar_med / med);
                printf("JSON {\"bench\":\"prefix_kernels_e2e\",\"m\":%zu,\"n\":%zu,"
                       "\"terms\":%llu,\"kernel\":\"%s\",\"median_ms\":%.3f,"
                       "\"mterms_per_s\":%.2f,\"speedup_vs_scalar\":%.2f}\n",
                       m, n, (unsigned long long)terms, KERNEL_NAMES[k], med * 1e3,
                       (double)terms / med / 1e6, scalar_med / med);
            }
            free(a);
        }
    }
}

/* ── 4. i128 Bareiss cofactor pass: alloc vs scratch ─────────────── */

/* Fraction-free Bareiss determinant of an w×w i64 matrix in __int128,
 * eliminating inside `elim` (caller-provided, length ≥ w²). */
static __int128 det_bareiss_i128(const int64_t *a, size_t w, __int128 *elim) {
    if (w == 0) return 1;
    for (size_t i = 0; i < w * w; i++) elim[i] = a[i];
    int sign = 1;
    __int128 prev = 1;
    for (size_t k = 0; k + 1 < w; k++) {
        if (elim[k * w + k] == 0) {
            size_t p = k + 1;
            while (p < w && elim[p * w + k] == 0) p++;
            if (p == w) return 0;
            for (size_t cc = 0; cc < w; cc++) {
                __int128 tmp = elim[k * w + cc];
                elim[k * w + cc] = elim[p * w + cc];
                elim[p * w + cc] = tmp;
            }
            sign = -sign;
        }
        for (size_t i = k + 1; i < w; i++)
            for (size_t j = k + 1; j < w; j++)
                elim[i * w + j] =
                    (elim[i * w + j] * elim[k * w + k] - elim[i * w + k] * elim[k * w + j]) / prev;
        prev = elim[k * w + k];
    }
    return sign > 0 ? elim[(w - 1) * w + (w - 1)] : -elim[(w - 1) * w + (w - 1)];
}

/* One cofactor pass: m minors of the m×(m−1) integer prefix. The alloc
 * arm mallocs the elimination buffer per pass (what cofactors_generic
 * did before the scratch hoist); the scratch arm reuses one buffer
 * (cofactors_into). minor_buf is shared by both arms, as in Rust. */
static __int128 cofactor_pass(const int64_t *prefix, size_t m, int64_t *minor_buf,
                              __int128 *elim_or_null) {
    size_t w = m - 1;
    __int128 *elim = elim_or_null ? elim_or_null
                                  : malloc((w ? w * w : 1) * sizeof(__int128));
    __int128 check = 0;
    for (size_t skip = 0; skip < m; skip++) {
        size_t r = 0;
        for (size_t i = 0; i < m; i++) {
            if (i == skip) continue;
            memcpy(minor_buf + r * w, prefix + i * w, w * sizeof(int64_t));
            r++;
        }
        __int128 d = det_bareiss_i128(minor_buf, w, elim);
        check += (skip % 2 == 0) ? d : -d;
    }
    if (!elim_or_null) free(elim);
    return check;
}

static void bench_scratch(void) {
    printf("\n## i128 Bareiss cofactor pass: alloc per call vs reused scratch\n");
    printf("%-3s %12s %12s %10s\n", "m", "alloc", "scratch", "speedup");
    static const size_t MS[] = {4, 5, 6};
    for (size_t mi = 0; mi < 3; mi++) {
        size_t m = MS[mi];
        size_t w = m - 1;
        int64_t prefix[6 * 5], minor_buf[5 * 5];
        rng_state = m * 7 + 1;
        for (size_t i = 0; i < m * w; i++) prefix[i] = rng_int(-60, 60);
        __int128 *scratch = malloc(w * w * sizeof(__int128));
        /* same arithmetic both arms — sanity first */
        if (cofactor_pass(prefix, m, minor_buf, NULL) !=
            cofactor_pass(prefix, m, minor_buf, scratch)) {
            fprintf(stderr, "scratch arm changed the cofactor sum\n");
            exit(1);
        }
        enum { REPS = 20000 };
        double med_alloc, med_scratch;
        MEDIAN_OF(med_alloc, {
            __int128 acc = 0;
            for (int r = 0; r < REPS; r++)
                acc += cofactor_pass(prefix, m, minor_buf, NULL);
            sink = (double)(int64_t)acc;
        });
        MEDIAN_OF(med_scratch, {
            __int128 acc = 0;
            for (int r = 0; r < REPS; r++)
                acc += cofactor_pass(prefix, m, minor_buf, scratch);
            sink = (double)(int64_t)acc;
        });
        printf("%-3zu %10.1f ns %10.1f ns %9.2fx\n", m,
               med_alloc / REPS * 1e9, med_scratch / REPS * 1e9,
               med_alloc / med_scratch);
        printf("JSON {\"bench\":\"scalar_scratch\",\"m\":%zu,\"scalar\":\"i128\","
               "\"alloc_ns\":%.1f,\"scratch_ns\":%.1f,\"speedup\":%.2f}\n",
               m, med_alloc / REPS * 1e9, med_scratch / REPS * 1e9,
               med_alloc / med_scratch);
        free(scratch);
    }
}

int main(void) {
    fprintf(stderr, "kernels: ");
    for (size_t k = 0; k < NKERNELS; k++)
        fprintf(stderr, "%s ", KERNEL_NAMES[k]);
    fprintf(stderr, "\n");
    check_bit_identity();
    bench_dot_isolated();
    bench_full_sweep();
    bench_scratch();
    return 0;
}
