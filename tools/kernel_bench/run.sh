#!/bin/sh
# Build and run the kernel-bench C harness.
#
# -ffp-contract=off is load-bearing: the determinism rule of the Rust
# kernels (mul then add, never fused) must hold here too, or the C
# numbers would time different arithmetic than the Rust kernels run.
# -mavx2/-mfma are only requested when the host has them.
set -eu
cd "$(dirname "$0")"
SIMD=""
if grep -qw avx2 /proc/cpuinfo 2>/dev/null; then
    SIMD="-mavx2 -mfma"
fi
gcc -O3 -ffp-contract=off $SIMD -o kernel_bench kernel_bench.c -lm
exec ./kernel_bench "$@"
