//! Reproduce the paper's §6 PRAM complexity table on the cost-model
//! simulator, sweeping problem sizes to show the O(m(n−m)) (⊂ O(n²))
//! shape empirically.
//!
//! ```bash
//! cargo run --release --example pram_analysis
//! ```

use raddet::pram::{analysis, section6_table, MemPolicy, PramMachine};

fn main() -> anyhow::Result<()> {
    println!("§6 reproduction — PRAM cost model (measured steps)\n");

    // The paper's running example plus a growth sweep with m = n/2
    // (the worst case for the m(n−m) term).
    let problems = [(8u64, 5u64), (12, 6), (16, 8), (20, 10), (24, 12), (28, 14)];
    let rows = section6_table(&problems)?;
    print!("{}", analysis::render(&rows));

    println!("\nphase breakdown at n=24, m=12:");
    for policy in MemPolicy::ALL {
        let r = PramMachine::new(policy).simulate(24, 12)?;
        println!(
            "  {:<5} broadcast={:<4} unrank={:<6} det={:<4} reduce={:<4}  total={} steps, {} processors",
            policy.name(),
            r.broadcast.time,
            r.unrank.time,
            r.det.time,
            r.reduce.time,
            r.time(),
            r.processors
        );
    }

    // The O(n²) claim, fitted.
    println!("\ntime/n² flatness (EREW, m = n/2):");
    for n in [8u64, 12, 16, 20, 24, 28] {
        let r = PramMachine::new(MemPolicy::Erew).simulate(n, n / 2)?;
        println!(
            "  n={n:<3} C(n,m)={:<12} time={:<6} time/n² = {:.3}",
            r.groups,
            r.time(),
            r.time() as f64 / (n * n) as f64
        );
    }
    println!("\n(flat time/n² while C(n,m) explodes ⇒ the paper's O(n²) shape holds)");
    Ok(())
}
