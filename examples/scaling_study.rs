//! END-TO-END DRIVER — the paper's headline experiment on a real
//! workload.
//!
//! Evaluates the Radić determinant of an 8×28 matrix — C(28,8) =
//! 3,108,105 signed 8×8 determinants — through the full system
//! (unranking → chunked streams → gather/batch → engine → compensated
//! reduce), sweeping worker counts and both scheduling policies, plus
//! the AOT/XLA engine, and verifies every configuration against the
//! single-worker result. Results are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example scaling_study
//! ```

use raddet::bench::{fmt_time, Table};
use raddet::combin::combination_count;
use raddet::coordinator::{Coordinator, CoordinatorConfig, EngineKind, Schedule};
use raddet::matrix::gen;
use raddet::runtime::resolve_artifact_dir;
use raddet::testkit::TestRng;

const M: usize = 8;
const N: usize = 28;

fn run(
    engine: EngineKind,
    schedule: Schedule,
    workers: usize,
    a: &raddet::matrix::MatF64,
) -> raddet::Result<raddet::coordinator::RadicOutput> {
    let coord = Coordinator::new(CoordinatorConfig {
        workers,
        engine,
        schedule,
        batch: 256,
        xla_executors: workers.min(4),
        ..Default::default()
    })?;
    coord.radic_det(a)
}

fn main() -> raddet::Result<()> {
    let total = combination_count(N as u64, M as u64)?;
    println!(
        "end-to-end workload: {M}×{N} uniform matrix ⇒ {total} Radić terms\n"
    );
    let a = gen::uniform(&mut TestRng::from_seed(7), M, N, -1.0, 1.0);

    let max_workers = std::thread::available_parallelism().map_or(8, |p| p.get());

    // Baseline: one worker, static.
    let base = run(EngineKind::Cpu, Schedule::Static, 1, &a)?;
    let t1 = base.metrics.elapsed.as_secs_f64();
    println!(
        "baseline (1 worker, cpu-lu): det = {:.9e}, {}\n",
        base.det,
        base.metrics.render()
    );

    let mut table = Table::new(&[
        "workers", "schedule", "engine", "time", "speedup", "efficiency", "Mterms/s", "rel-err",
    ]);
    let mut w = 1;
    while w <= max_workers {
        for (schedule, sname) in [
            (Schedule::Static, "static"),
            (Schedule::WorkStealing { grain: 4096 }, "steal"),
        ] {
            let out = run(EngineKind::Cpu, schedule, w, &a)?;
            let secs = out.metrics.elapsed.as_secs_f64();
            let err = (out.det - base.det).abs() / base.det.abs().max(1.0);
            assert!(err < 1e-9, "worker-count changed the determinant!");
            table.row(&[
                w.to_string(),
                sname.into(),
                "cpu-lu".into(),
                fmt_time(secs),
                format!("{:.2}×", t1 / secs),
                format!("{:.0}%", 100.0 * t1 / secs / w as f64),
                format!("{:.2}", total as f64 / secs / 1e6),
                format!("{err:.1e}"),
            ]);
        }
        w *= 2;
    }

    // The prefix-factored engine: same workers, per-term cost
    // amortized from O(m³) down to an O(m) Laplace dot per sibling.
    let mut w = 1;
    while w <= max_workers {
        let out = run(EngineKind::Prefix, Schedule::Static, w, &a)?;
        let secs = out.metrics.elapsed.as_secs_f64();
        let err = (out.det - base.det).abs() / base.det.abs().max(1.0);
        assert!(err < 1e-9, "prefix path disagrees: {err:.3e}");
        table.row(&[
            w.to_string(),
            "static".into(),
            "prefix".into(),
            fmt_time(secs),
            format!("{:.2}×", t1 / secs),
            format!("{:.0}%", 100.0 * t1 / secs / w as f64),
            format!("{:.2}", total as f64 / secs / 1e6),
            format!("{err:.1e}"),
        ]);
        w *= 2;
    }

    // The three-layer AOT/XLA path, if artifacts are built.
    if resolve_artifact_dir(None).is_some() {
        for w in [2, max_workers.max(2)] {
            let out = run(EngineKind::Xla, Schedule::Static, w, &a)?;
            let secs = out.metrics.elapsed.as_secs_f64();
            let err = (out.det - base.det).abs() / base.det.abs().max(1.0);
            assert!(err < 1e-9, "xla path disagrees: {err:.3e}");
            table.row(&[
                w.to_string(),
                "static".into(),
                "xla-pjrt".into(),
                fmt_time(secs),
                format!("{:.2}×", t1 / secs),
                format!("{:.0}%", 100.0 * t1 / secs / w as f64),
                format!("{:.2}", total as f64 / secs / 1e6),
                format!("{err:.1e}"),
            ]);
        }
    } else {
        eprintln!("(artifacts not built — skipping the xla-pjrt rows)");
    }

    print!("{}", table.render());
    println!("\nall configurations agree with the 1-worker baseline ✓");
    Ok(())
}
