//! The §8 distributed setting: start the determinant service, run a
//! client workload against it, and report the network overhead —
//! the `O(n² + network_overhead)` term, measured.
//!
//! ```bash
//! cargo run --release --example det_service
//! ```

use raddet::coordinator::{Coordinator, CoordinatorConfig, EngineKind};
use raddet::matrix::gen;
use raddet::service::{Client, Server};
use raddet::testkit::TestRng;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    // Server on an ephemeral port (in-process, loopback).
    let coord = Coordinator::new(CoordinatorConfig {
        engine: EngineKind::Auto,
        ..Default::default()
    })?;
    let handle = Server::new(coord).start("127.0.0.1:0")?;
    let addr = handle.addr().to_string();
    println!("service up on {addr}");

    // Local coordinator for the no-network baseline.
    let local = Coordinator::new(CoordinatorConfig {
        engine: EngineKind::Auto,
        ..Default::default()
    })?;

    let mut client = Client::connect(&addr)?;
    client.ping()?;

    println!("\n{:<10} {:>12} {:>14} {:>14} {:>12}", "shape", "terms", "local", "via service", "overhead");
    for (m, n) in [(3usize, 12usize), (4, 16), (5, 18), (6, 20)] {
        let a = gen::uniform(&mut TestRng::from_seed((m * n) as u64), m, n, -1.0, 1.0);

        // Warm both paths once: the first request per (m, batch) bucket
        // pays the one-time XLA compile (the coordinator caches the
        // dispatcher afterwards) — steady-state latency is what the §8
        // network-overhead question is about.
        let _ = local.radic_det(&a)?;
        let _ = client.det(&a)?;

        let t0 = Instant::now();
        let want = local.radic_det(&a)?;
        let local_time = t0.elapsed();

        let reply = client.det(&a)?;
        assert!(
            (reply.det - want.det).abs() < 1e-9 * want.det.abs().max(1.0),
            "service result diverged"
        );
        let overhead = reply.round_trip.saturating_sub(local_time);
        println!(
            "{:<10} {:>12} {:>14?} {:>14?} {:>12?}",
            format!("{m}×{n}"),
            reply.terms,
            local_time,
            reply.round_trip,
            overhead
        );
    }

    client.quit();
    println!("\nrequests served: {}", handle.requests());
    handle.stop();
    Ok(())
}
