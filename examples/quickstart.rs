//! Quickstart: compute a Radić determinant through the full stack and
//! cross-check every engine against the exact integer reference.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use raddet::coordinator::{Coordinator, CoordinatorConfig, EngineKind, Schedule};
use raddet::linalg::{radic_det_exact, radic_det_seq};
use raddet::matrix::gen;
use raddet::testkit::TestRng;

fn main() -> raddet::Result<()> {
    // A 5×12 integer matrix: small enough to print, big enough to be
    // non-trivial (C(12,5) = 792 Radić terms).
    let ai = gen::integer(&mut TestRng::from_seed(2015), 5, 12, -9, 9);
    let a = ai.map(|x| x as f64);
    println!("matrix (5×12, integer entries):");
    for r in 0..ai.rows() {
        println!("  {:?}", ai.row(r));
    }

    // Ground truth: exact integer enumeration (Bareiss, no rounding).
    let exact = radic_det_exact(&ai)?;
    println!("\nexact integer Radić det  = {exact}");

    // Sequential float baseline.
    let seq = radic_det_seq(&a)?;
    println!("sequential (LU, Neumaier) = {seq:.6}");

    // Parallel, CPU engine.
    let cpu = Coordinator::new(CoordinatorConfig {
        engine: EngineKind::Cpu,
        schedule: Schedule::Static,
        ..Default::default()
    })?;
    let out = cpu.radic_det(&a)?;
    println!(
        "parallel cpu-lu           = {:.6}   [{}]",
        out.det,
        out.metrics.render()
    );

    // Parallel, prefix-factored engine: each sibling block's shared
    // m×(m−1) prefix is factorized once, every sibling determinant is
    // an O(m) Laplace dot — the sub-O(m³)-per-term path.
    let pre = Coordinator::new(CoordinatorConfig {
        engine: EngineKind::Prefix,
        schedule: Schedule::Static,
        ..Default::default()
    })?;
    let out = pre.radic_det(&a)?;
    println!(
        "parallel prefix           = {:.6}   [{}]",
        out.det,
        out.metrics.render()
    );

    // Parallel, XLA engine (AOT JAX/Pallas artifact via PJRT) — the
    // three-layer path. Auto falls back to CPU if artifacts are absent.
    let xla = Coordinator::new(CoordinatorConfig {
        engine: EngineKind::Auto,
        ..Default::default()
    })?;
    let out = xla.radic_det(&a)?;
    println!(
        "parallel {}        = {:.6}   [{}]",
        out.engine,
        out.det,
        out.metrics.render()
    );

    let err = (out.det - exact as f64).abs() / (exact as f64).abs().max(1.0);
    println!("\nrelative error vs exact: {err:.3e}");
    assert!(err < 1e-9, "engines disagree with the exact reference");
    println!("all engines agree ✓");
    Ok(())
}
