//! The paper's motivating application (§1, refs [8][20–23]): retrieving
//! images **of different sizes** with a non-square-determinant
//! signature.
//!
//! Indexes a small synthetic gallery (every image a different
//! resolution), then queries with noisy, re-sized copies and reports
//! precision@1.
//!
//! ```bash
//! cargo run --release --example image_retrieval
//! ```

use raddet::apps::retrieval::{ImageStore, SyntheticImage};
use raddet::coordinator::{Coordinator, CoordinatorConfig, EngineKind};
use raddet::testkit::TestRng;

fn main() -> anyhow::Result<()> {
    let coord = Coordinator::new(CoordinatorConfig {
        // CPU engine: signature jobs are tiny (≤ C(12,4) terms); the
        // XLA path is exercised by quickstart/scaling_study instead.
        engine: EngineKind::Cpu,
        batch: 64,
        ..Default::default()
    })?;

    // Gallery: 10 scenes, each rendered at its own resolution.
    let gallery = 10u64;
    let mut store = ImageStore::new();
    println!("indexing {gallery} images (all different sizes):");
    for seed in 0..gallery {
        let h = 24 + (seed as usize % 4) * 8;
        let w = 30 + (seed as usize % 5) * 9;
        let img = SyntheticImage::generate(seed, h, w);
        println!("  img{seed}: {h}×{w}");
        store.add(&format!("img{seed}"), &img, &coord)?;
    }

    // Queries: each scene re-rendered at a NEW resolution + pixel noise.
    let mut hits = 0;
    let mut rng = TestRng::from_seed(777);
    println!("\nquerying with re-sized, noisy copies:");
    for seed in 0..gallery {
        let probe = SyntheticImage::generate(seed, 40, 52).noisy(&mut rng, 0.02);
        let top = store.query(&probe, &coord, 3)?;
        let hit = top[0].0 == format!("img{seed}");
        hits += hit as u32;
        println!(
            "  query img{seed} (40×52+noise) → {:?} {}",
            top.iter().map(|(l, d)| format!("{l}:{d:.3}")).collect::<Vec<_>>(),
            if hit { "✓" } else { "✗" }
        );
    }
    let p1 = hits as f64 / gallery as f64;
    println!("\nprecision@1 = {p1:.2} ({hits}/{gallery})");
    assert!(p1 >= 0.7, "retrieval quality collapsed");
    Ok(())
}
